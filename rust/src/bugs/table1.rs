//! The Table-1 harness: arm each of the 14 bugs in its native parallel
//! configuration, run the full TTrace workflow, and report
//! detection + localization. Shared by `cargo test` (assertions) and
//! `cargo bench --bench table1_bugs` (prints the paper's table).

use anyhow::Result;

use crate::data::GenData;
use crate::model::{ModelCfg, ParCfg};
use crate::runtime::Executor;
use crate::ttrace::{localized_module, ttrace_check, CheckCfg};

use super::{BugId, BugSet};

pub struct Table1Row {
    pub number: u32,
    pub new: bool,
    pub btype: &'static str,
    pub description: &'static str,
    pub impact: &'static str,
    pub config: String,
    pub detected: bool,
    pub localized: Option<String>,
    pub localization_ok: bool,
    /// dependency-aware diagnosis: blamed module, implicated dimension,
    /// phase (from `ttrace::diagnose`)
    pub diagnosed_module: Option<String>,
    pub diagnosed_dim: Option<String>,
    pub diagnosed_phase: Option<String>,
    /// diagnosis matches the bug's ground-truth module+dimension+phase
    pub diagnosis_ok: bool,
}

/// The armed parallel configuration for one bug on the given model.
pub fn bug_config(bug: BugId) -> ParCfg {
    let mut p = ParCfg::single();
    bug.arm_parcfg(&mut p);
    p
}

/// Run TTrace against one armed bug. `layers` must suit the config
/// (pp*vpp | layers).
pub fn run_one(bug: BugId, m: &ModelCfg, layers: usize, exec: &Executor)
               -> Result<Table1Row> {
    let info = bug.info();
    let p = bug_config(bug);
    let run = ttrace_check(m, &p, layers, exec, &GenData, BugSet::one(bug),
                           &CheckCfg::default(), true)?;
    let detected = !run.outcome.pass;
    let localized = localized_module(&run);
    let localization_ok = match &localized {
        Some(module) => {
            info.expect_module.is_empty() || module.contains(info.expect_module)
        }
        None => false,
    };
    let (diagnosed_module, diagnosed_dim, diagnosed_phase) = match &run.diagnosis {
        Some(d) => (d.module.clone(),
                    d.dims.first().map(|(dim, _)| dim.name().to_string()),
                    d.phase.map(|p| p.name().to_string())),
        None => (None, None, None),
    };
    let diagnosis_ok = diagnosis_matches(&info, diagnosed_module.as_deref(),
                                         diagnosed_dim.as_deref(),
                                         diagnosed_phase.as_deref());
    Ok(Table1Row {
        number: info.number,
        new: info.new,
        btype: info.btype.name(),
        description: info.description,
        impact: info.impact,
        config: format!("{}{}{}{}{}",
                        p.topo.describe(),
                        if p.sp { "+sp" } else { "" },
                        if p.fp8 { "+fp8" } else { "" },
                        if p.moe { "+moe" } else { "" },
                        if p.zero1 { "+zero1" } else { "" }),
        detected,
        localized,
        localization_ok,
        diagnosed_module,
        diagnosed_dim,
        diagnosed_phase,
        diagnosis_ok,
    })
}

/// Ground-truth match rule shared by the test suite and the bench table:
/// the blamed module must contain the expected substring, the top
/// implicated dimension must equal the expected one (none expected ->
/// none implicated), and the phase must match.
pub fn diagnosis_matches(info: &crate::bugs::BugInfo, module: Option<&str>,
                         dim: Option<&str>, phase: Option<&str>) -> bool {
    let m_ok = match module {
        Some(m) => info.expect_module.is_empty() || m.contains(info.expect_module),
        None => false,
    };
    let dim_ok = if info.expect_dim == "none" {
        dim.is_none()
    } else {
        dim == Some(info.expect_dim)
    };
    let ph_ok = phase == Some(info.expect_phase);
    m_ok && dim_ok && ph_ok
}

/// Run the whole table.
pub fn run_all(m: &ModelCfg, layers: usize, exec: &Executor)
               -> Result<Vec<Table1Row>> {
    BugId::all().iter().map(|&b| run_one(b, m, layers, exec)).collect()
}

/// Sanity counterpart: the same armed *configurations* with no bug must
/// all PASS (no false positives) — the paper's §6.2 sweep.
pub fn run_clean_sweep(m: &ModelCfg, layers: usize, exec: &Executor)
                       -> Result<Vec<(String, bool)>> {
    let mut seen = std::collections::BTreeSet::new();
    let mut out = Vec::new();
    for bug in BugId::all() {
        let p = bug_config(bug);
        let key = format!("{}sp{}fp8{}moe{}z{}rc{}ov{}",
                          p.topo.describe(), p.sp, p.fp8, p.moe, p.zero1,
                          p.recompute, p.overlap);
        if !seen.insert(key.clone()) {
            continue;
        }
        let run = ttrace_check(m, &p, layers, exec, &GenData, BugSet::none(),
                               &CheckCfg::default(), false)?;
        out.push((key, run.outcome.pass));
    }
    Ok(out)
}
