//! The Table-1 harness: arm each of the 14 bugs in its native parallel
//! configuration, run the full TTrace workflow, and report
//! detection + localization. Shared by `cargo test` (assertions) and
//! `cargo bench --bench table1_bugs` (prints the paper's table).

use anyhow::Result;

use crate::data::GenData;
use crate::model::{ModelCfg, ParCfg};
use crate::runtime::Executor;
use crate::ttrace::{localized_module, ttrace_check, CheckCfg};

use super::{BugId, BugSet};

pub struct Table1Row {
    pub number: u32,
    pub new: bool,
    pub btype: &'static str,
    pub description: &'static str,
    pub impact: &'static str,
    pub config: String,
    pub detected: bool,
    pub localized: Option<String>,
    pub localization_ok: bool,
}

/// The armed parallel configuration for one bug on the given model.
pub fn bug_config(bug: BugId) -> ParCfg {
    let mut p = ParCfg::single();
    bug.arm_parcfg(&mut p);
    p
}

/// Run TTrace against one armed bug. `layers` must suit the config
/// (pp*vpp | layers).
pub fn run_one(bug: BugId, m: &ModelCfg, layers: usize, exec: &Executor)
               -> Result<Table1Row> {
    let info = bug.info();
    let p = bug_config(bug);
    let run = ttrace_check(m, &p, layers, exec, &GenData, BugSet::one(bug),
                           &CheckCfg::default(), true)?;
    let detected = !run.outcome.pass;
    let localized = localized_module(&run);
    let localization_ok = match &localized {
        Some(module) => {
            info.expect_module.is_empty() || module.contains(info.expect_module)
        }
        None => false,
    };
    Ok(Table1Row {
        number: info.number,
        new: info.new,
        btype: info.btype.name(),
        description: info.description,
        impact: info.impact,
        config: format!("{}{}{}{}{}",
                        p.topo.describe(),
                        if p.sp { "+sp" } else { "" },
                        if p.fp8 { "+fp8" } else { "" },
                        if p.moe { "+moe" } else { "" },
                        if p.zero1 { "+zero1" } else { "" }),
        detected,
        localized,
        localization_ok,
    })
}

/// Run the whole table.
pub fn run_all(m: &ModelCfg, layers: usize, exec: &Executor)
               -> Result<Vec<Table1Row>> {
    BugId::all().iter().map(|&b| run_one(b, m, layers, exec)).collect()
}

/// Sanity counterpart: the same armed *configurations* with no bug must
/// all PASS (no false positives) — the paper's §6.2 sweep.
pub fn run_clean_sweep(m: &ModelCfg, layers: usize, exec: &Executor)
                       -> Result<Vec<(String, bool)>> {
    let mut seen = std::collections::BTreeSet::new();
    let mut out = Vec::new();
    for bug in BugId::all() {
        let p = bug_config(bug);
        let key = format!("{}sp{}fp8{}moe{}z{}rc{}ov{}",
                          p.topo.describe(), p.sp, p.fp8, p.moe, p.zero1,
                          p.recompute, p.overlap);
        if !seen.insert(key.clone()) {
            continue;
        }
        let run = ttrace_check(m, &p, layers, exec, &GenData, BugSet::none(),
                               &CheckCfg::default(), false)?;
        out.push((key, run.outcome.pass));
    }
    Ok(out)
}
