//! `ttrace` — CLI for the TTrace reproduction.
//!
//! Subcommands:
//!   check   run the full differential check of a candidate configuration
//!           (optionally with an injected bug) against its reference
//!   train   run training and print the loss curve
//!   bugs    list the 14 reproducible Table-1 bugs
//!
//! Examples:
//!   ttrace check --model tiny --tp 2 --layers 2
//!   ttrace check --model tiny --tp 2 --bug 1 --localize
//!   ttrace train --model e2e --steps 100 --tp 2
//!   ttrace bugs

use anyhow::{bail, Result};

use ttrace::bugs::{BugId, BugSet};
use ttrace::data::{CorpusData, DataSource, GenData};
use ttrace::dist::Topology;
use ttrace::model::{mean_losses, preset, run_training, Engine, ParCfg};
use ttrace::runtime::Executor;
use ttrace::ttrace::{localized_module, report, ttrace_check, CheckCfg, NoopHooks};
use ttrace::util::bench::{fmt_s, time_once};
use ttrace::util::cli::Cli;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("check") => run(check(&argv[1..])),
        Some("train") => run(train(&argv[1..])),
        Some("bugs") => run(bugs()),
        _ => {
            eprintln!("usage: ttrace <check|train|bugs> [options]\n\
                       run `ttrace check --help` etc. for details");
            2
        }
    };
    std::process::exit(code);
}

fn run(r: Result<i32>) -> i32 {
    match r {
        Ok(code) => code,
        Err(e) => {
            eprintln!("{e:#}");
            2
        }
    }
}

fn parcfg_cli(cli: Cli) -> Cli {
    cli.opt("model", "tiny", "model preset (tiny|small|e2e)")
        .opt("layers", "0", "layer count (0 = preset default)")
        .opt("dp", "1", "data parallel degree")
        .opt("tp", "1", "tensor parallel degree")
        .opt("pp", "1", "pipeline parallel degree")
        .opt("cp", "1", "context parallel degree")
        .opt("vpp", "1", "virtual pipeline chunks per stage")
        .opt("micro", "1", "microbatches per iteration")
        .flag("sp", "sequence parallelism")
        .flag("fp8", "fp8 (e4m3-emulated) linears")
        .flag("moe", "dense top-1 MoE MLPs")
        .flag("zero1", "ZeRO-1 distributed optimizer")
        .flag("recompute", "activation recomputation")
        .opt("data", "synthetic", "data source (synthetic|corpus)")
}

fn parse_parcfg(args: &ttrace::util::cli::Args) -> Result<(ttrace::model::ModelCfg, ParCfg, usize)> {
    let m = preset(args.get("model"))?;
    let mut p = ParCfg::single();
    p.topo = Topology::new(args.get_usize("dp")?, args.get_usize("tp")?,
                           args.get_usize("pp")?, args.get_usize("cp")?,
                           args.get_usize("vpp")?)?;
    p.sp = args.flag("sp");
    p.fp8 = args.flag("fp8");
    p.moe = args.flag("moe");
    p.zero1 = args.flag("zero1");
    p.recompute = args.flag("recompute");
    p.n_micro = args.get_usize("micro")?;
    let layers = match args.get_usize("layers")? {
        0 => (p.topo.pp * p.topo.vpp).max(2),
        l => l,
    };
    Ok((m, p, layers))
}

fn data_source(kind: &str, vocab: usize) -> Result<Box<dyn DataSource>> {
    Ok(match kind {
        "synthetic" => Box::new(GenData),
        "corpus" => Box::new(CorpusData::builtin(vocab)),
        _ => bail!("unknown --data '{kind}' (synthetic|corpus)"),
    })
}

fn find_bug(no: usize) -> Result<BugId> {
    BugId::all()
        .iter()
        .copied()
        .find(|b| b.info().number == no as u32)
        .ok_or_else(|| anyhow::anyhow!("bug number must be 1..=14"))
}

fn check(argv: &[String]) -> Result<i32> {
    let cli = parcfg_cli(Cli::new("TTrace differential check"))
        .opt("bug", "0", "inject Table-1 bug number (0 = none)")
        .opt("safety", "8", "threshold safety multiplier")
        .flag("localize", "run the input-rewrite localization pass on failure")
        .opt("out", "", "write the JSON report to this path");
    let args = cli.parse_from(argv)?;
    let (m, mut p, layers) = parse_parcfg(&args)?;
    let bug_no = args.get_usize("bug")?;
    let bugs = if bug_no == 0 {
        BugSet::none()
    } else {
        let bug = find_bug(bug_no)?;
        bug.arm_parcfg(&mut p);
        BugSet::one(bug)
    };
    let cfg = CheckCfg { safety: args.get_f64("safety")?, ..CheckCfg::default() };
    let exec = Executor::load(ttrace::default_artifacts_dir())?;
    let data = data_source(args.get("data"), m.v)?;
    let (run_res, dt) = time_once(|| {
        ttrace_check(&m, &p, layers, &exec, data.as_ref(), bugs, &cfg,
                     args.flag("localize"))
    });
    let run_out = run_res?;
    println!("{}", report::render(&run_out.outcome, &cfg, 32));
    if args.flag("localize") {
        if let Some(module) = localized_module(&run_out) {
            println!("localization: {module}");
        }
    }
    println!("total check time: {}", fmt_s(dt));
    let out = args.get("out");
    if !out.is_empty() {
        std::fs::write(out, report::to_json(&run_out.outcome, &cfg).to_string_pretty())?;
        println!("wrote {out}");
    }
    Ok(if run_out.outcome.pass { 0 } else { 1 })
}

fn train(argv: &[String]) -> Result<i32> {
    let cli = parcfg_cli(Cli::new("train and print the loss curve"))
        .opt("steps", "10", "training iterations")
        .opt("bug", "0", "inject Table-1 bug number (0 = none)");
    let args = cli.parse_from(argv)?;
    let (m, mut p, layers) = parse_parcfg(&args)?;
    let bug_no = args.get_usize("bug")?;
    let bugs = if bug_no == 0 {
        BugSet::none()
    } else {
        let bug = find_bug(bug_no)?;
        bug.arm_parcfg(&mut p);
        BugSet::one(bug)
    };
    let exec = Executor::load(ttrace::default_artifacts_dir())?;
    let data = data_source(args.get("data"), m.v)?;
    let engine = Engine::new(m, p.clone(), layers, &exec, bugs)?;
    println!("training '{}' ({} layers, ~{:.1}M params) on {}",
             m.name, layers, m.param_count(layers) as f64 / 1e6,
             p.topo.describe());
    let steps = args.get_usize("steps")? as u64;
    let (losses, dt) = time_once(|| {
        mean_losses(&run_training(&engine, data.as_ref(), &NoopHooks, steps))
    });
    for (i, l) in losses.iter().enumerate() {
        println!("step {i:>4}  loss {l:.4}");
    }
    println!("{} steps in {} ({} / step)", steps, fmt_s(dt),
             fmt_s(dt / steps as f64));
    // per-module profile (the §Perf instrument)
    let st = exec.stats();
    let mut mods: Vec<(&String, &(u64, f64))> = st.per_module.iter().collect();
    mods.sort_by(|a, b| b.1 .1.partial_cmp(&a.1 .1).unwrap());
    println!("\nruntime: {} execs, compile {}, execute {}, marshal {}",
             st.executions, fmt_s(st.compile_s), fmt_s(st.execute_s),
             fmt_s(st.marshal_s));
    println!("top modules by device time:");
    for (k, (n, t)) in mods.iter().take(10) {
        println!("  {:<40} {:>6} execs  {:>10}  ({} avg)",
                 k, n, fmt_s(*t), fmt_s(*t / *n as f64));
    }
    Ok(0)
}

fn bugs() -> Result<i32> {
    println!("{:<4} {:<4} {:<5} {:<42} {}", "ID", "New", "Type",
             "Description", "Impact");
    for b in BugId::all() {
        let i = b.info();
        println!("{:<4} {:<4} {:<5} {:<42} {}", i.number,
                 if i.new { "yes" } else { "" }, i.btype.name(),
                 i.description, i.impact);
    }
    Ok(0)
}
