//! `ttrace` — CLI for the TTrace reproduction.
//!
//! Subcommands:
//!   check          run the full differential check of a candidate
//!                  configuration (optionally with an injected bug)
//!                  against its reference, in-process
//!   record         run one traced iteration and persist it as a binary
//!                  `.ttrc` store (reference or candidate side)
//!   check-offline  differential check of two `.ttrc` stores recorded by
//!                  separate `record` invocations (separate processes or
//!                  machines — the paper's deployment mode)
//!   diagnose       differential check of two `.ttrc` stores + the
//!                  dependency-aware diagnosis: divergence frontier,
//!                  blamed module, phase, implicated parallelism dimension
//!   inspect        describe a `.ttrc` store (ids, shapes, shard layouts);
//!                  `--id` dumps one tensor's shards and summary stats
//!   lint           pre-run static lint: diff the config's expected trace
//!                  schema and collective plan against a clean layout —
//!                  flags misconfigurations before any step runs;
//!                  `--store` also schema-diffs a recorded `.ttrc` store
//!   check-hang     run training under a deadline with an injected fault
//!                  plan (`ttrace::faults` grammar) and print the
//!                  structured hang/crash verdicts — op kind, group key,
//!                  missing ranks, per-rank last-completed progress
//!   timeline       export a store's run telemetry (recorded with
//!                  `record --telemetry`) as Chrome trace-event JSON —
//!                  loadable in Perfetto / `chrome://tracing` — plus a
//!                  per-rank text summary
//!   serve          run the live monitoring daemon: a TCP endpoint that
//!                  aggregates per-step status pushed by `record --live
//!                  --monitor` sessions and exposes `/status` (JSON) and
//!                  `/metrics` (Prometheus text exposition) over HTTP
//!   collect        run the central segment collector (`ttrace::mesh`):
//!                  accept `record --segment --push` pushes over TCP,
//!                  spool each process' segment, and merge them into one
//!                  whole-world store when the run is complete —
//!                  optionally check-offline against a reference
//!   estimate       §5.2 threshold estimation from three recorded stores
//!                  (reference run, identical rerun, `--perturb` run):
//!                  writes a reference store with the estimates embedded
//!   train          run training and print the loss curve
//!   bugs           list the 14 reproducible Table-1 bugs
//!
//! Examples:
//!   ttrace check --model tiny --tp 2 --layers 2
//!   ttrace check --model tiny --tp 2 --bug 1 --localize
//!   ttrace record --tp 2 --reference --out ref.ttrc
//!   ttrace record --tp 2 --bug 1 --out cand.ttrc
//!   ttrace record --tp 2 --telemetry --out cand.ttrc
//!   ttrace record --dp 2 --out torn.ttrc --checkpoint-every 8 \
//!                 --fault 'crash@1:0/0/layers.1'
//!   ttrace serve --addr 127.0.0.1:9090 --max-runs 64 --ttl-secs 86400
//!   ttrace record --tp 2 --bug 12 --sp --steps 4 --out cand.ttrc \
//!                 --live ref.ttrc --monitor 127.0.0.1:9090 \
//!                 --stop-on-divergence
//!   ttrace collect --world 2 --spool spool/ --out merged.ttrc \
//!                  --reference ref.ttrc
//!   ttrace record --tp 2 --segment --proc-id 0/2 \
//!                 --push 127.0.0.1:9191 --out seg0.ttrc
//!   ttrace record --out base.ttrc && ttrace record --out rerun.ttrc
//!   ttrace record --perturb 0.0078 --out pert.ttrc
//!   ttrace estimate base.ttrc rerun.ttrc pert.ttrc --out ref_est.ttrc
//!   ttrace check-offline ref.ttrc cand.ttrc
//!   ttrace check-offline ref.ttrc torn.ttrc --salvage
//!   ttrace diagnose ref.ttrc cand.ttrc
//!   ttrace diagnose ref.ttrc cand.ttrc --tp 2 --dp 2 --fp8
//!   ttrace check-hang --dp 2 --fault 'stall@1:dp@' --deadline-ms 500
//!   ttrace timeline cand.ttrc --out trace.json
//!   ttrace inspect ref.ttrc
//!   ttrace inspect ref.ttrc --id i0/m0/act/layers.0.mlp
//!   ttrace lint --tp 2 --sp --bug 12
//!   ttrace lint --tp 2 --store cand.ttrc --out findings.json
//!   ttrace train --model e2e --steps 100 --tp 2
//!   ttrace bugs

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use ttrace::bugs::{BugId, BugSet};
use ttrace::data::{CorpusData, DataSource, GenData};
use ttrace::dist::Topology;
use ttrace::model::{mean_losses, preset, run_training, run_training_until,
                    try_run_training, try_run_training_until, Engine, ParCfg};
use ttrace::prelude::{localized_module, merge_segments, reference_of,
                      ttrace_check, CheckCfg, FaultPlan, NoopHooks,
                      RankFailure, Report, SegmentCollector, SegmentInfo,
                      Session, Sink, SpmdOpts, StoreReader, StoreWriter,
                      Telemetry, Timeline, Tolerance, Trace, TraceMode};
use ttrace::runtime::Executor;
use ttrace::ttrace::analyze::{self, diff_schema, findings_json,
                              render_findings, ExpectedSchema,
                              ObservedSchema};
use ttrace::ttrace::live::warn_if_nonloopback;
use ttrace::ttrace::store::{layout_of, write_trace, Encoding};
use ttrace::ttrace::{mesh, report, threshold};
use ttrace::util::bench::{fmt_bytes, fmt_s, time_once};
use ttrace::util::cli::Cli;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("check") => run(check(&argv[1..])),
        Some("record") => run(record(&argv[1..])),
        Some("check-offline") => run(check_offline(&argv[1..])),
        Some("diagnose") => run(diagnose_cmd(&argv[1..])),
        Some("check-hang") => run(check_hang(&argv[1..])),
        Some("timeline") => run(timeline_cmd(&argv[1..])),
        Some("inspect") => run(inspect(&argv[1..])),
        Some("lint") => run(lint(&argv[1..])),
        Some("serve") => run(serve(&argv[1..])),
        Some("collect") => run(collect(&argv[1..])),
        Some("estimate") => run(estimate_cmd(&argv[1..])),
        Some("train") => run(train(&argv[1..])),
        Some("bugs") => run(bugs()),
        _ => {
            eprintln!("usage: ttrace <check|record|check-offline|diagnose|\
                       check-hang|timeline|inspect|lint|serve|collect|\
                       estimate|train|bugs> [options]\n\
                       run `ttrace check --help` etc. for details");
            2
        }
    };
    std::process::exit(code);
}

fn run(r: Result<i32>) -> i32 {
    match r {
        Ok(code) => code,
        Err(e) => {
            eprintln!("{e:#}");
            2
        }
    }
}

fn parcfg_cli(cli: Cli) -> Cli {
    cli.opt("model", "tiny", "model preset (tiny|small|e2e)")
        .opt("layers", "0", "layer count (0 = preset default)")
        .opt("dp", "1", "data parallel degree")
        .opt("tp", "1", "tensor parallel degree")
        .opt("pp", "1", "pipeline parallel degree")
        .opt("cp", "1", "context parallel degree")
        .opt("vpp", "1", "virtual pipeline chunks per stage")
        .opt("micro", "1", "microbatches per iteration")
        .flag("sp", "sequence parallelism")
        .flag("fp8", "fp8 (e4m3-emulated) linears")
        .flag("moe", "dense top-1 MoE MLPs")
        .flag("zero1", "ZeRO-1 distributed optimizer")
        .flag("recompute", "activation recomputation")
        .opt("data", "synthetic", "data source (synthetic|corpus)")
}

fn parse_parcfg(args: &ttrace::util::cli::Args) -> Result<(ttrace::model::ModelCfg, ParCfg, usize)> {
    let m = preset(args.get("model"))?;
    let mut p = ParCfg::single();
    p.topo = Topology::new(args.get_usize("dp")?, args.get_usize("tp")?,
                           args.get_usize("pp")?, args.get_usize("cp")?,
                           args.get_usize("vpp")?)?;
    p.sp = args.flag("sp");
    p.fp8 = args.flag("fp8");
    p.moe = args.flag("moe");
    p.zero1 = args.flag("zero1");
    p.recompute = args.flag("recompute");
    p.n_micro = args.get_usize("micro")?;
    let layers = match args.get_usize("layers")? {
        0 => (p.topo.pp * p.topo.vpp).max(2),
        l => l,
    };
    Ok((m, p, layers))
}

fn data_source(kind: &str, vocab: usize) -> Result<Box<dyn DataSource>> {
    Ok(match kind {
        "synthetic" => Box::new(GenData),
        "corpus" => Box::new(CorpusData::builtin(vocab)),
        _ => bail!("unknown --data '{kind}' (synthetic|corpus)"),
    })
}

fn find_bug(no: usize) -> Result<BugId> {
    BugId::all()
        .iter()
        .copied()
        .find(|b| b.info().number == no as u32)
        .ok_or_else(|| anyhow::anyhow!("bug number must be 1..=14"))
}

fn check(argv: &[String]) -> Result<i32> {
    let cli = parcfg_cli(Cli::new("TTrace differential check"))
        .opt("bug", "0", "inject Table-1 bug number (0 = none)")
        .opt("safety", "8", "threshold safety multiplier")
        .flag("localize", "run the input-rewrite localization pass on failure")
        .opt("out", "", "write the JSON report to this path");
    let args = cli.parse_from(argv)?;
    let (m, mut p, layers) = parse_parcfg(&args)?;
    let bug_no = args.get_usize("bug")?;
    let bugs = if bug_no == 0 {
        BugSet::none()
    } else {
        let bug = find_bug(bug_no)?;
        bug.arm_parcfg(&mut p);
        BugSet::one(bug)
    };
    let cfg = CheckCfg { safety: args.get_f64("safety")?, ..CheckCfg::default() };
    let exec = Executor::load(ttrace::default_artifacts_dir())?;
    let data = data_source(args.get("data"), m.v)?;
    let (run_res, dt) = time_once(|| {
        ttrace_check(&m, &p, layers, &exec, data.as_ref(), bugs, &cfg,
                     args.flag("localize"))
    });
    let run_out = run_res?;
    println!("{}", report::render(&run_out.outcome, &cfg, 32));
    if let Some(d) = &run_out.diagnosis {
        println!("{}", report::render_diagnosis(d, &cfg));
    }
    if args.flag("localize") {
        if let Some(module) = localized_module(&run_out) {
            println!("localization: {module}");
        }
    }
    println!("total check time: {}", fmt_s(dt));
    let out = args.get("out");
    if !out.is_empty() {
        let mut j = report::to_json(&run_out.outcome, &cfg);
        if let Some(d) = &run_out.diagnosis {
            j.set("diagnosis", report::diagnosis_json(d));
        }
        std::fs::write(out, j.to_string_pretty())?;
        println!("wrote {out}");
    }
    Ok(if run_out.outcome.pass { 0 } else { 1 })
}

fn record(argv: &[String]) -> Result<i32> {
    let cli = parcfg_cli(Cli::new("run one traced iteration and persist it \
                                   as a binary .ttrc trace store"))
        .opt("bug", "0", "Table-1 bug number (0 = none). Injected into a \
                          candidate run; with --reference it only arms the \
                          bug's parallel config (dp/fp8/moe/...) so the \
                          recorded reference matches that candidate")
        .req("out", "output .ttrc path")
        .opt("steps", "1", "training iterations to record")
        .opt("live", "", "stream-check every step online against this \
                          reference .ttrc store while recording: the async \
                          sink's streaming checker emits a per-step verdict \
                          the moment each iteration's window closes \
                          (ttrace::live)")
        .opt("monitor", "", "with --live: push per-step status to a `ttrace \
                             serve` daemon at this host:port (best-effort — \
                             an unreachable daemon never fails the run)")
        .opt("run-id", "", "run id reported on the daemon's /status and \
                            /metrics (default: the --out file stem)")
        .flag("stop-on-divergence", "with --live: raise the session's stop \
                                     flag at the first failing step — the \
                                     ranks agree on the flag collectively \
                                     and all halt at the next iteration \
                                     boundary")
        .opt("json", "", "also dump the trace as (bit-exact) debug JSON here")
        .opt("fault", "", "inject a deterministic fault plan (ttrace::faults \
                           grammar, e.g. 'crash@1:0/0/layers.1' or \
                           'truncate;seed:7') — the run survives and exits \
                           nonzero")
        .opt("checkpoint-every", "0", "write a crash-tolerance checkpoint \
                                       into the store every N shard payloads \
                                       (0 = off); a torn store salvages back \
                                       to its last checkpoint")
        .opt("deadline-ms", "0", "rendezvous wait deadline while a fault \
                                  plan is armed (0 = the comm default)")
        .flag("telemetry", "record run telemetry into the store: module \
                            fwd/bwd spans, every collective rendezvous as a \
                            first-class comm entry, store I/O — export with \
                            `ttrace timeline`. Off by default because the \
                            wall-clock stamps make the store bytes vary run \
                            to run")
        .flag("segment", "record this process' share of a multi-process run \
                          (ttrace::mesh): the store carries a segment header \
                          and persists only the ranks --proc-id assigns to \
                          this process — merge with `ttrace collect` or \
                          `merge_segments`")
        .opt("proc-id", "", "with --segment: which process this is, as K/N \
                             (process K of N); the world's ranks are split \
                             into N contiguous partitions")
        .opt("push", "", "with --segment: after sealing the store, push it \
                          to the `ttrace collect` endpoint at this host:port \
                          (checksummed, resumable frames)")
        .opt("push-attempts", "5", "connection attempts for --push \
                                    (exponential backoff between attempts)")
        .opt("perturb", "0", "record under the §5.2 input perturbation at \
                              this relative magnitude (0 = off) — the third \
                              run of the `ttrace estimate` recipe")
        .flag("reference", "record this config's single-device reference and \
                            embed per-tensor threshold estimates");
    let args = cli.parse_from(argv)?;
    let (m, mut p, layers) = parse_parcfg(&args)?;
    let is_ref = args.flag("reference");
    let bug_no = args.get_usize("bug")?;
    // Arming must happen on both sides — some bugs change the parallel
    // config (dp, fp8, moe), and the reference is derived from the *armed*
    // candidate config, exactly as in-process `ttrace_check` does. Only a
    // candidate run actually injects the fault; the reference is trusted.
    let bugs = if bug_no == 0 {
        BugSet::none()
    } else {
        let bug = find_bug(bug_no)?;
        bug.arm_parcfg(&mut p);
        if is_ref { BugSet::none() } else { BugSet::one(bug) }
    };
    if is_ref {
        p = reference_of(&p);
    }
    let cfg = CheckCfg::default();
    let exec = Executor::load(ttrace::default_artifacts_dir())?;
    let data = data_source(args.get("data"), m.v)?;
    let out = std::path::PathBuf::from(args.get("out"));
    let json_path = args.get("json").to_string();
    let steps = args.get_usize("steps")? as u64;
    let est = if is_ref {
        // the §5.2 estimates ride along in the store so `check-offline`
        // derives the same thresholds as the in-process workflow; they
        // must cover every recorded iteration
        Some(threshold::estimate(&m, &p, layers, &exec, data.as_ref(),
                                 cfg.eps as f32, steps)?)
    } else {
        None
    };
    // The session streams into the store at finish — which only touches
    // --out once the run has succeeded, so a failure above can't truncate
    // a previously recorded store at the same path. `parallelism` embeds
    // the run's layout so `diagnose` can map shard rank tags to
    // (tp, cp, dp, pp) coordinates offline.
    let fault_spec = args.get("fault");
    let plan = if fault_spec.is_empty() {
        None
    } else {
        Some(Arc::new(FaultPlan::parse(fault_spec)?))
    };
    let tel = args.flag("telemetry").then(Telemetry::new);
    let push_addr = args.get("push").to_string();
    let segment = if args.flag("segment") {
        let spec = args.get("proc-id");
        if spec.is_empty() {
            bail!("--segment needs --proc-id K/N (which process of the \
                   world this one is)");
        }
        if !json_path.is_empty() {
            bail!("--segment records a per-process partial store; drop \
                   --json (dump the merged store instead)");
        }
        let (proc_id, proc_count) = parse_proc_id(spec)?;
        let ranks = mesh::rank_range(p.topo.world(), proc_id, proc_count)?;
        Some(SegmentInfo { proc_id, proc_count, ranks })
    } else {
        if !push_addr.is_empty() {
            bail!("--push streams a segment store; add --segment \
                   --proc-id K/N");
        }
        None
    };
    let mut builder = Session::builder().parallelism(&p)
        .checkpoint_every(args.get_usize("checkpoint-every")?)
        .sink(if json_path.is_empty() { Sink::Store(out.clone()) }
              else { Sink::Tee(out.clone()) });
    if let Some(seg) = &segment {
        builder = builder.segment(seg.clone());
    }
    let perturb = args.get_f64("perturb")?;
    if perturb > 0.0 {
        if is_ref {
            bail!("--perturb records the estimation recipe's third run; \
                   drop --reference (`ttrace estimate` builds the reference \
                   store from the three runs)");
        }
        builder = builder.mode(TraceMode::Perturb {
            modules: threshold::input_modules(),
            eps: perturb as f32,
        });
    }
    if let Some(est) = &est {
        builder = builder.embed_estimate(&est.rel, cfg.eps);
    }
    if let Some(plan) = &plan {
        builder = builder.faults(plan.clone());
    }
    if let Some(tel) = &tel {
        builder = builder.telemetry(tel.clone());
    }
    let live_ref = args.get("live").to_string();
    if !live_ref.is_empty() {
        if is_ref {
            bail!("--live stream-checks a candidate run; drop --reference \
                   (the trusted store is the one passed to --live)");
        }
        let run_id = if args.get("run-id").is_empty() {
            out.file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "run".to_string())
        } else {
            args.get("run-id").to_string()
        };
        let mut lc = ttrace::prelude::LiveCfg::new().run_id(run_id);
        if !args.get("monitor").is_empty() {
            lc = lc.monitor(args.get("monitor"));
        }
        if args.flag("stop-on-divergence") {
            lc = lc.stop_on_divergence();
        }
        builder = builder.live(
            ttrace::prelude::Reference::store(Path::new(&live_ref)), lc)?;
    }
    let live = !live_ref.is_empty();
    let mut session = builder.build();
    let stop = session.stop_flag();
    let engine = Engine::new(m, p.clone(), layers, &exec, bugs)?;
    let mut failed_ranks = 0usize;
    let dt = if plan.is_some() || tel.is_some() {
        // fault-tolerant run: a crashed or stalled rank must not deadlock
        // the recorder — whatever its thread-local buffers flushed before
        // dying still reaches the store below. (The telemetry path rides
        // the same runner because arming the World with the handle is an
        // opts-only affair.)
        let dl = args.get_usize("deadline-ms")?;
        let opts = SpmdOpts {
            deadline: (dl > 0).then(|| Duration::from_millis(dl as u64)),
            faults: plan.clone(),
            telemetry: tel.clone(),
        };
        let (results, dt) = time_once(|| if live {
            try_run_training_until(&engine, data.as_ref(), session.hooks(),
                                   steps, opts, &stop)
        } else {
            try_run_training(&engine, data.as_ref(), session.hooks(), steps,
                             opts)
        });
        for r in &results {
            if let Err(f) = r {
                failed_ranks += 1;
                eprintln!("rank failure: {f}");
            }
        }
        session.note_rank_failures(&results);
        dt
    } else {
        let (_, dt) = time_once(|| if live {
            run_training_until(&engine, data.as_ref(), session.hooks(),
                               steps, &stop);
        } else {
            run_training(&engine, data.as_ref(), session.hooks(), steps);
        });
        dt
    };
    let rep = session.finish()?;
    let (_, summary) = rep.store.as_ref().expect("store sink persists");
    println!("recorded {} ({}) on {}: {} ids / {} shards, {} payload, \
              {} file, run {}",
             out.display(), if is_ref { "reference" } else { "candidate" },
             p.topo.describe(), summary.ids, summary.shards,
             fmt_bytes(summary.payload_bytes), fmt_bytes(summary.file_bytes),
             fmt_s(dt));
    if let Some(seg) = &segment {
        println!("segment: process {}/{} holding rank(s) {:?} of the \
                  {}-rank world", seg.proc_id, seg.proc_count, seg.ranks,
                 p.topo.world());
    }
    if let Some((events, counters)) = &rep.obs {
        println!("telemetry: {} events sealed into the store ({} trace \
                  entries, {} comm ops, {} dropped) — `ttrace timeline {}`",
                 events.len(), counters.trace_entries, counters.comm_ops,
                 counters.dropped, out.display());
    }
    let mut live_failed = false;
    // a plain async store also carries an (empty) live summary — only its
    // queue counters mean anything, so stay quiet unless a checker ran or
    // the queue actually misbehaved
    if let Some(lv) = rep.live()
        .filter(|lv| !lv.steps.is_empty() || lv.overflow > 0 || lv.stalls > 0)
    {
        let failed = lv.steps.iter().filter(|s| !s.pass).count();
        println!("live: {} step window(s) checked, {} failed{}{}; {} \
                  flagged, {} queue overflow / {} stalls (high water {}), \
                  {} late entries",
                 lv.steps.len(), failed,
                 lv.first_diverging
                     .map(|it| format!(", first diverging step {it}"))
                     .unwrap_or_default(),
                 lv.stopped_at
                     .map(|it| format!(", stopped at step {it}"))
                     .unwrap_or_default(),
                 lv.flagged, lv.overflow, lv.stalls, lv.queue_high_water,
                 lv.late_entries);
        for s in lv.steps.iter().filter(|s| !s.pass) {
            println!("  step {:>3} FAIL: {} of {} checks past threshold \
                      ({} missing, {} merge errors), worst {} at {:.1}x",
                     s.iter, s.failed, s.checks, s.missing, s.merge_errors,
                     s.worst_id, s.worst_ratio);
        }
        live_failed = !lv.clean() || lv.stopped_at.is_some();
    }
    if !json_path.is_empty() {
        rep.trace.as_ref().expect("tee sink keeps the trace")
            .save(Path::new(&json_path))?;
        println!("wrote JSON dump {} ({})", json_path,
                 fmt_bytes(std::fs::metadata(&json_path)?.len()));
    }
    if let Some(plan) = &plan {
        // store-byte faults tear the sealed file after the fact — the
        // `open_salvage` / `check-offline --salvage` drill input
        if plan.has_store_faults() {
            for line in plan.corrupt_store(&out)? {
                eprintln!("injected: {line}");
            }
        }
        if failed_ranks > 0 || plan.has_store_faults() {
            eprintln!("fault injection: {} rank(s) failed; store {} is a \
                       drill artifact, not a clean recording",
                      failed_ranks, out.display());
            return Ok(1);
        }
    }
    if !push_addr.is_empty() {
        let attempts = args.get_usize("push-attempts")?;
        let (res, dt) = time_once(|| mesh::push_segment(&push_addr, &out,
                                                        attempts));
        res?;
        println!("pushed {} to collector {} ({})", out.display(), push_addr,
                 fmt_s(dt));
    }
    Ok(if live_failed { 1 } else { 0 })
}

/// Parse `--proc-id K/N` (process K of N, 0-based).
fn parse_proc_id(spec: &str) -> Result<(u32, u32)> {
    let parse = || -> Option<(u32, u32)> {
        let (k, n) = spec.split_once('/')?;
        Some((k.trim().parse().ok()?, n.trim().parse().ok()?))
    };
    parse().ok_or_else(|| anyhow::anyhow!(
        "--proc-id must be K/N (e.g. 0/2 for the first of two recording \
         processes), got '{spec}'"))
}

/// Shared head of the two-store subcommands (`check-offline`, `diagnose`):
/// positional/option registration, store opening, and the tolerance policy
/// (the eps override from the reference's embedded estimates is applied by
/// `Report::from_readers`).
fn store_pair_cli(about: &'static str) -> Cli {
    Cli::new(about)
        .pos("reference.ttrc", "store from `ttrace record --reference`")
        .pos("candidate.ttrc", "store from the candidate run")
        .opt("safety", "8", "threshold safety multiplier")
        .opt("rows", "32", "max report rows before passing tensors are elided")
        .opt("out", "", "write the JSON report to this path")
        .flag("salvage", "open the candidate through the torn-store salvage \
                          path: recover the longest valid checkpointed \
                          prefix and report unrecovered ids as INCOMPLETE \
                          coverage instead of failing")
}

fn open_store_pair(args: &ttrace::util::cli::Args)
                   -> Result<(StoreReader, StoreReader, Tolerance)> {
    let reference = StoreReader::open(Path::new(args.pos(0)))?;
    let candidate = if args.flag("salvage") {
        let (reader, info) = StoreReader::open_salvage(Path::new(args.pos(1)))?;
        if info.complete {
            eprintln!("salvage: {} is intact — full open", args.pos(1));
        } else {
            eprintln!("salvage: {} recovered {} id(s) / {} shard(s) from \
                       bytes [0, {}) of {} — the rest of the file is torn",
                      args.pos(1), info.recovered_ids, info.recovered_shards,
                      info.valid_prefix, info.file_len);
        }
        reader
    } else {
        StoreReader::open(Path::new(args.pos(1)))?
    };
    let tolerance = Tolerance::new().safety(args.get_f64("safety")?);
    if reference.estimate().is_empty() {
        eprintln!("note: {} carries no threshold estimates (recorded without \
                   --reference?); falling back to the floor threshold",
                  args.pos(0));
    }
    Ok((reference, candidate, tolerance))
}

fn check_offline(argv: &[String]) -> Result<i32> {
    let cli = store_pair_cli("differential check of two .ttrc stores \
                              recorded by separate `ttrace record` runs");
    let args = cli.parse_from(argv)?;
    let (reference, candidate, tolerance) = open_store_pair(&args)?;
    // verdict-only path: skips the diagnosis this subcommand never prints
    let (res, dt) = time_once(|| Report::check_readers(&reference, &candidate,
                                                       &tolerance));
    let rep = res?;
    println!("{}", rep.render(args.get_usize("rows")?));
    println!("offline check time: {} ({} ids; {} + {} of payload read \
              one canonical id at a time)",
             fmt_s(dt), reference.len(),
             fmt_bytes(reference.payload_bytes()),
             fmt_bytes(candidate.payload_bytes()));
    let out = args.get("out");
    if !out.is_empty() {
        let outcome = rep.outcome.as_ref().expect("offline reports check");
        std::fs::write(out, report::to_json(outcome, &rep.cfg)
            .to_string_pretty())?;
        println!("wrote {out}");
    }
    Ok(rep.exit_code())
}

/// Differential check + dependency-aware diagnosis of two `.ttrc` stores,
/// from the files alone (the offline twin of `check --bug N`). When the
/// candidate carries comm telemetry (`record --telemetry`) and the
/// record-time layout flags are supplied, the observed collectives are
/// also cross-referenced against the statically derived plan — a
/// collective that ran on the wrong group, never ran, or ran unplanned
/// becomes a `comm/<op>/<group>` vertex at the head of the frontier.
fn diagnose_cmd(argv: &[String]) -> Result<i32> {
    let cli = parcfg_cli(store_pair_cli(
        "differential check + dependency-aware bug localization over two \
         .ttrc stores: divergence frontier, blamed module, phase, \
         implicated parallelism dimension. Pass the candidate's record-time \
         layout flags (--tp/--dp/...) to also cross-reference its comm \
         telemetry against the static collective plan"));
    let args = cli.parse_from(argv)?;
    let (reference, candidate, tolerance) = open_store_pair(&args)?;
    let (res, dt) = time_once(|| Report::from_readers(&reference, &candidate,
                                                      &tolerance));
    let mut rep = res?;
    let comm_findings = xref_store_comm(&args, &candidate)?;
    if let (Some(d), false) = (&mut rep.diagnosis, comm_findings.is_empty()) {
        ttrace::ttrace::diagnose::note_comm_findings(d, &comm_findings);
    }
    println!("{}", rep.render(args.get_usize("rows")?));
    println!("{}", rep.render_diagnosis());
    println!("diagnose time: {} ({} ids; frontier analyzed from the stores \
              one canonical id at a time)", fmt_s(dt), reference.len());
    let out = args.get("out");
    if !out.is_empty() {
        std::fs::write(out, rep.to_json().to_string_pretty())?;
        println!("wrote {out}");
    }
    Ok(if comm_findings.is_empty() { rep.exit_code() } else { 1 })
}

/// Cross-reference a candidate store's comm telemetry against the clean
/// collective plan of the layout given on the command line. Returns no
/// findings (and warns, where appropriate) when the store carries no comm
/// telemetry or the supplied layout does not match the recorded topology —
/// a plan built for the wrong grid would flag every op.
fn xref_store_comm(args: &ttrace::util::cli::Args, candidate: &StoreReader)
                   -> Result<Vec<analyze::CommFinding>> {
    if !candidate.obs_events().iter().any(|e| e.comm.is_some()) {
        return Ok(Vec::new());
    }
    let (m, p, layers) = parse_parcfg(args)?;
    match candidate.run_meta() {
        Some(meta) if meta.topo != p.topo => {
            eprintln!("note: {} carries comm telemetry recorded on {}, but \
                       the supplied layout is {} — skipping the collective \
                       cross-reference (pass the record-time --tp/--dp/... \
                       flags)",
                      args.pos(1), meta.topo.describe(), p.topo.describe());
            return Ok(Vec::new());
        }
        None if p.topo.world() == 1 => return Ok(Vec::new()),
        _ => {}
    }
    // the plan must cover every recorded iteration: infer the count from
    // the store's canonical ids ("i<n>/...")
    let iters = candidate
        .keys()
        .filter_map(|k| k.strip_prefix('i')?.split('/').next()?
                        .parse::<u64>().ok())
        .max()
        .map(|n| n + 1)
        .unwrap_or(1);
    let plan = analyze::CollectivePlan::build(&m, &p, layers,
                                              BugSet::none(), iters)?;
    Ok(analyze::xref_comm(&plan, candidate.obs_events()))
}

/// Robustness drill: run training under a short rendezvous deadline with
/// an injected fault plan and print the structured hang/crash verdicts —
/// op kind, group key, arrived-vs-missing rank sets, each missing rank's
/// last-completed collective, and (when the static plan can place it) the
/// planned op the hang maps to. Exit 0 when every rank completed, 1 when
/// any rank hung or crashed.
fn check_hang(argv: &[String]) -> Result<i32> {
    let cli = parcfg_cli(Cli::new("run training under a deadline with an \
                                   injected fault plan and print the \
                                   structured hang verdicts"))
        .opt("bug", "0", "inject Table-1 bug number (0 = none)")
        .opt("fault", "", "fault plan (ttrace::faults grammar), e.g. \
                           'stall@1:dp@' or 'straggler@0:tp@:50'")
        .opt("deadline-ms", "2000", "rendezvous wait deadline per collective")
        .opt("steps", "1", "training iterations");
    let args = cli.parse_from(argv)?;
    let (m, mut p, layers) = parse_parcfg(&args)?;
    let bug_no = args.get_usize("bug")?;
    let bugs = if bug_no == 0 {
        BugSet::none()
    } else {
        let bug = find_bug(bug_no)?;
        bug.arm_parcfg(&mut p);
        BugSet::one(bug)
    };
    let fault_spec = args.get("fault");
    let plan = if fault_spec.is_empty() {
        None
    } else {
        Some(Arc::new(FaultPlan::parse(fault_spec)?))
    };
    let deadline = Duration::from_millis(args.get_usize("deadline-ms")? as u64);
    let steps = args.get_usize("steps")? as u64;
    let exec = Executor::load(ttrace::default_artifacts_dir())?;
    let data = data_source(args.get("data"), m.v)?;
    let mut builder = Session::builder().parallelism(&p);
    if let Some(plan) = &plan {
        builder = builder.faults(plan.clone());
    }
    let mut session = builder.build();
    let engine = Engine::new(m, p.clone(), layers, &exec, bugs)?;
    let opts = SpmdOpts { deadline: Some(deadline), faults: plan.clone(),
                          ..Default::default() };
    let (results, dt) = time_once(|| {
        try_run_training(&engine, data.as_ref(), session.hooks(), steps, opts)
    });
    // the statically derived collective plan places a hang's runtime key
    // at a named call site ("which grad-sync never happened")
    let static_plan = analyze::CollectivePlan::build(&m, &p, layers, bugs,
                                                     steps)?;
    let mut failures = 0usize;
    for r in &results {
        let Err(f) = r else { continue };
        failures += 1;
        match f {
            RankFailure::Hang(h) => {
                println!("{}", h.render());
                if let Some(op) = static_plan.locate(h.waiter, &h.key) {
                    println!("  planned op: {} at site '{}' ({} elems, \
                              group size {})",
                             op.kind.name(), op.site, op.elems, op.size);
                }
            }
            other => println!("{other}"),
        }
    }
    session.note_rank_failures(&results);
    let rep = session.finish()?;
    if failures == 0 {
        println!("no hangs: {} rank(s) completed {} step(s) in {} \
                  (deadline {}ms)",
                 p.topo.world(), steps, fmt_s(dt), deadline.as_millis());
        Ok(0)
    } else {
        println!("{} of {} rank(s) failed ({} structured hang verdict(s)) \
                  in {} — deadline {}ms",
                 failures, p.topo.world(), rep.hangs().len(), fmt_s(dt),
                 deadline.as_millis());
        Ok(1)
    }
}

/// Export a store's run telemetry as a Chrome trace-event timeline
/// (loadable in Perfetto / `chrome://tracing`) plus a per-rank text
/// summary. Works on any v3 store recorded with `record --telemetry`.
fn timeline_cmd(argv: &[String]) -> Result<i32> {
    let cli = Cli::new("export a recorded .ttrc store's run telemetry as a \
                        Chrome trace-event timeline")
        .pos("store.ttrc", "a store from `ttrace record --telemetry`")
        .opt("out", "", "write the Chrome trace-event JSON here");
    let args = cli.parse_from(argv)?;
    let store = StoreReader::open(Path::new(args.pos(0)))?;
    let tl = Timeline::from_store(&store);
    if tl.events.is_empty() {
        println!("{}: no run telemetry in the store (ttrc v{}) — record \
                  with `ttrace record --telemetry` to capture a timeline",
                 args.pos(0), store.version());
    } else {
        print!("{}", tl.render_summary());
    }
    let out = args.get("out");
    if !out.is_empty() {
        std::fs::write(out, tl.chrome_json().to_string_pretty())?;
        println!("wrote {out} — open it in Perfetto (ui.perfetto.dev) or \
                  chrome://tracing");
    }
    Ok(0)
}

fn inspect(argv: &[String]) -> Result<i32> {
    let cli = Cli::new("describe a .ttrc trace store")
        .pos("store.ttrc", "the store to describe")
        .opt("limit", "40", "max canonical ids to list (0 = all)")
        .opt("id", "", "dump one canonical id: shard specs, dtype, ranks \
                        and summary stats (min/max/mean/checksum)")
        .flag("salvage", "open a torn store through the salvage path and \
                          report how much of it was recovered");
    let args = cli.parse_from(argv)?;
    let store = if args.flag("salvage") {
        let (reader, info) = StoreReader::open_salvage(Path::new(args.pos(0)))?;
        if info.complete {
            println!("salvage: {} is intact — full open", args.pos(0));
        } else {
            println!("salvage coverage: recovered {} id(s) / {} shard(s) \
                      from bytes [0, {}) of {} ({:.0}% of the file) — the \
                      rest is torn",
                     info.recovered_ids, info.recovered_shards,
                     info.valid_prefix, info.file_len,
                     info.valid_prefix as f64 / info.file_len.max(1) as f64
                         * 100.0);
        }
        reader
    } else {
        StoreReader::open(Path::new(args.pos(0)))?
    };
    let id = args.get("id");
    if !id.is_empty() {
        return inspect_id(&store, args.pos(0), id);
    }
    println!("{}: ttrc v{}, {} canonical ids, {} shards, {} payload \
              ({} file)",
             args.pos(0), store.version(), store.len(), store.shard_count(),
             fmt_bytes(store.payload_bytes()), fmt_bytes(store.file_bytes()));
    if let Some(eps) = store.estimate_eps() {
        println!("embedded threshold estimates: {} tensors (eps {:.3e})",
                 store.estimate().len(), eps);
    }
    if let Some(m) = store.run_meta() {
        println!("recorded on {} (micro {}{}{}{}{}{})",
                 m.topo.describe(), m.n_micro,
                 if m.sp { ", sp" } else { "" },
                 if m.fp8 { ", fp8" } else { "" },
                 if m.moe { ", moe" } else { "" },
                 if m.zero1 { ", zero1" } else { "" },
                 if m.overlap { ", overlap" } else { "" });
    }
    inspect_obs(&store);
    inspect_live(&store);
    let limit = args.get_usize("limit")?;
    println!();
    println!("{:<52} {:<5} {:<18} {:>6} {:>10}  layout",
             "canonical id", "dtype", "global dims", "shards", "bytes");
    let mut shown = 0usize;
    for key in store.keys() {
        if limit != 0 && shown >= limit {
            println!("... {} more ids (raise --limit) ...",
                     store.len() - shown);
            break;
        }
        shown += 1;
        let metas = store.shards(key).expect("key from the index");
        let bytes: u64 = metas.iter().map(|m| m.len).sum();
        println!("{:<52} {:<5} {:<18} {:>6} {:>10}  {}",
                 key, metas[0].dtype.name(),
                 format!("{:?}", metas[0].spec.global_dims), metas.len(),
                 bytes, layout_of(metas));
    }
    Ok(0)
}

/// The obs section of `inspect`: telemetry counters plus the first few
/// first-class collective entries (v3 stores recorded with
/// `record --telemetry`; silent for v2 / unarmed stores).
fn inspect_obs(store: &StoreReader) {
    let events = store.obs_events();
    if events.is_empty() {
        return;
    }
    if let Some(c) = store.obs_counters() {
        println!("run telemetry: {} events ({} trace entries, {} comm ops, \
                  {} dropped)",
                 c.events, c.trace_entries, c.comm_ops, c.dropped);
        for (group, bytes) in &c.bytes_by_group {
            println!("  comm payload on {group}: {}", fmt_bytes(*bytes));
        }
        if c.check_ids > 0 {
            println!("  checker: {} ids in {:.3} s", c.check_ids, c.check_s);
        }
    }
    const SHOW: usize = 8;
    let comm: Vec<&ttrace::prelude::ObsEvent> =
        events.iter().filter(|e| e.comm.is_some()).collect();
    if !comm.is_empty() {
        println!("  first {} of {} collective entries:",
                 SHOW.min(comm.len()), comm.len());
        for e in comm.iter().take(SHOW) {
            let c = e.comm.as_ref().expect("filtered on comm");
            println!("    rank {:>2}: {} on {} ({} elems, group size {}, \
                      checksum {:016x})",
                     e.rank, c.op, c.group, c.elems, c.size, c.checksum);
        }
    }
}

/// The live section of `inspect`: the sealed per-step verdict history of
/// the recording session's streaming checker (v4 stores recorded with
/// `record --live`; silent otherwise).
fn inspect_live(store: &StoreReader) {
    let Some(lv) = store.live() else { return };
    let failed = lv.steps.iter().filter(|s| !s.pass).count();
    println!("live section: {} step window(s), {} failed{}{}; {} flagged, \
              {} queue overflow / {} stalls (high water {}), {} late \
              entries",
             lv.steps.len(), failed,
             lv.first_diverging
                 .map(|it| format!(", first diverging step {it}"))
                 .unwrap_or_default(),
             lv.stopped_at
                 .map(|it| format!(", stopped at step {it}"))
                 .unwrap_or_default(),
             lv.flagged, lv.overflow, lv.stalls, lv.queue_high_water,
             lv.late_entries);
    for s in &lv.steps {
        if s.pass {
            println!("  step {:>3} pass: {} checks", s.iter, s.checks);
        } else {
            println!("  step {:>3} FAIL: {} of {} checks past threshold \
                      ({} missing, {} merge errors), worst {} at {:.1}x",
                     s.iter, s.failed, s.checks, s.missing, s.merge_errors,
                     s.worst_id, s.worst_ratio);
        }
    }
}

/// `inspect --id`: dump one canonical id's shard specs, dtype and summary
/// stats (min/max/mean/checksum), loading its payloads from the store.
fn inspect_id(store: &StoreReader, store_name: &str, id: &str) -> Result<i32> {
    let Some(metas) = store.shards(id) else {
        bail!("{store_name}: no canonical id '{id}' in the store (run \
               `ttrace inspect {store_name}` for the id list)");
    };
    let entries = store
        .read_entries(id)?
        .expect("id came from the store index");
    println!("{id}: {} shard(s), dtype {}, global dims {:?}, layout: {}{}",
             metas.len(), metas[0].dtype.name(), metas[0].spec.global_dims,
             layout_of(metas),
             if metas[0].spec.partial { " [partial sums]" } else { "" });
    for (i, (m, e)) in metas.iter().zip(&entries).enumerate() {
        let t = &e.data;
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in &t.data {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        if t.data.is_empty() {
            mn = 0.0;
            mx = 0.0;
        }
        let checksum = ttrace::util::rng::fnv1a(&t.to_le_bytes());
        let maps: Vec<String> = m
            .spec
            .maps
            .iter()
            .map(|mp| format!("dim{} {}", mp.dim,
                              mp.pieces.iter()
                                  .map(|p| format!("[{},{})", p.global_start,
                                                   p.global_start + p.len))
                                  .collect::<Vec<_>>()
                                  .join("+")))
            .collect();
        println!("  shard {i}: rank {}, local dims {:?}, {} ({} payload \
                  bytes at offset {})",
                 m.rank, t.dims,
                 match m.encoding {
                     Encoding::Raw32 => "raw32",
                     Encoding::Packed16 => "packed16",
                 },
                 m.len, m.offset);
        println!("    spec: {}", if maps.is_empty() { "full".to_string() }
                                 else { maps.join(", ") });
        println!("    stats: min {mn:.6e}  max {mx:.6e}  mean {:.6e}  \
                  checksum {checksum:#018x}", t.mean());
    }
    if let Some(est) = store.estimate().get(id) {
        println!("  embedded threshold estimate: {est:.6e}");
    }
    Ok(0)
}

/// Pre-run static lint: derive the expected trace schema and collective
/// plan from `(ModelCfg, ParCfg)` alone and diff them against the clean
/// layout — no training step, no compiled artifacts. Exit 0 when clean,
/// 1 when any finding fires.
fn lint(argv: &[String]) -> Result<i32> {
    let cli = parcfg_cli(Cli::new("pre-run static lint of the expected \
                                   trace schema and collective plan"))
        .opt("bug", "0", "arm Table-1 bug number (0 = none) and lint the \
                          armed config — nothing is executed")
        .opt("iters", "1", "iterations the expected schema should cover")
        .opt("store", "", "also schema-diff this recorded .ttrc store \
                           against the expected schema")
        .opt("out", "", "write the JSON findings to this path");
    let args = cli.parse_from(argv)?;
    let (m, mut p, layers) = parse_parcfg(&args)?;
    let bug_no = args.get_usize("bug")?;
    let bugs = if bug_no == 0 {
        BugSet::none()
    } else {
        let bug = find_bug(bug_no)?;
        bug.arm_parcfg(&mut p);
        BugSet::one(bug)
    };
    let iters = args.get_usize("iters")? as u64;
    let (res, dt) = time_once(|| analyze::lint_config(&m, &p, layers, bugs,
                                                      iters));
    let mut findings = res?;
    let store_path = args.get("store");
    if !store_path.is_empty() {
        // instrumentation lint: the recorded id set vs what the (armed)
        // config's run should have recorded
        let store = StoreReader::open(Path::new(store_path))?;
        let observed = ObservedSchema::of_store(&store);
        let expected = ExpectedSchema::build(&m, &p, layers, bugs,
                                             observed.infer_iters())?;
        findings.extend(diff_schema(&expected, &observed));
    }
    if findings.is_empty() {
        println!("lint clean: '{}' on {} — no findings ({})", m.name,
                 p.topo.describe(), fmt_s(dt));
    } else {
        println!("{}", render_findings(&findings));
        println!("{} finding(s) on {} ({})", findings.len(),
                 p.topo.describe(), fmt_s(dt));
    }
    let out = args.get("out");
    if !out.is_empty() {
        std::fs::write(out, findings_json(&findings).to_string_pretty())?;
        println!("wrote {out}");
    }
    Ok(if findings.is_empty() { 0 } else { 1 })
}

/// The live monitoring daemon: one TCP port aggregating per-step status
/// pushed by `record --live --monitor` sessions (newline-delimited JSON
/// events) and serving `/status` (JSON) and `/metrics` (Prometheus text
/// exposition 0.0.4) to HTTP scrapers.
fn serve(argv: &[String]) -> Result<i32> {
    let cli = Cli::new("run the live monitoring daemon: /status (JSON) + \
                        /metrics (Prometheus) plus the session event \
                        endpoint, all on one port")
        .opt("addr", "127.0.0.1:9090", "listen address (host:port; port 0 \
                                        picks an ephemeral port). The \
                                        default stays on loopback — the \
                                        endpoint is unauthenticated, so \
                                        binding wider is an explicit, \
                                        warned-about choice")
        .opt("max-runs", "0", "retain at most this many runs, evicting the \
                               least recently updated first (0 = unbounded); \
                               evictions surface on /metrics as \
                               ttrace_evicted_runs_total")
        .opt("ttl-secs", "0", "drop a run this long after its last event \
                               (0 = never)");
    let args = cli.parse_from(argv)?;
    warn_if_nonloopback(args.get("addr"));
    let ttl = args.get_usize("ttl-secs")?;
    let mon = ttrace::prelude::Monitor::bind(args.get("addr"))?
        .retention(args.get_usize("max-runs")?,
                   (ttl > 0).then(|| Duration::from_secs(ttl as u64)));
    let addr = mon.local_addr();
    println!("ttrace serve: listening on {addr}");
    println!("  GET http://{addr}/status   per-run state as JSON");
    println!("  GET http://{addr}/metrics  Prometheus text exposition");
    println!("  sessions push with `ttrace record --live ref.ttrc \
              --monitor {addr} ...`");
    mon.serve_forever()?;
    Ok(0)
}

/// The central segment collector (`ttrace::mesh`): spool `record --segment
/// --push` pushes until every process of the world has sealed its segment,
/// then merge them into one whole-world store — and, with `--reference`,
/// run the same differential check `check-offline` would, from one command.
fn collect(argv: &[String]) -> Result<i32> {
    let cli = Cli::new("run the segment collector: accept `record --segment \
                        --push` pushes over TCP, spool each process' \
                        segment, merge into one whole-world .ttrc when the \
                        run is complete, and optionally check it against a \
                        reference store")
        .opt("addr", "127.0.0.1:9191", "listen address (host:port; port 0 \
                                        picks an ephemeral port). Loopback \
                                        by default — the push protocol is \
                                        unauthenticated")
        .req("world", "recording processes to wait for (the N of their \
                       --proc-id K/N)")
        .opt("spool", "", "spool dir for incoming segments (default: \
                           <out>.spool); sealed segments already there \
                           count, so a restarted collector resumes")
        .opt("out", "merged.ttrc", "write the merged whole-world store here")
        .opt("reference", "", "after merging, differentially check the \
                               merged store against this reference .ttrc \
                               (the exit code becomes the check's)")
        .opt("timeout-secs", "0", "give up waiting after this many seconds, \
                                   naming the processes still missing \
                                   (0 = wait forever)")
        .opt("safety", "8", "threshold safety multiplier for --reference")
        .opt("rows", "32", "max report rows for --reference");
    let args = cli.parse_from(argv)?;
    let addr = args.get("addr");
    warn_if_nonloopback(addr);
    let world = args.get_usize("world")? as u32;
    let out = std::path::PathBuf::from(args.get("out"));
    let spool = if args.get("spool").is_empty() {
        out.with_extension("ttrc.spool")
    } else {
        std::path::PathBuf::from(args.get("spool"))
    };
    let col = SegmentCollector::bind(addr, world, &spool)?;
    let local = col.local_addr()?;
    println!("ttrace collect: listening on {local}, spooling {world} \
              segment(s) into {}", spool.display());
    println!("  recorders push with `ttrace record --segment \
              --proc-id K/{world} --push {local} ...`");
    let timeout = args.get_usize("timeout-secs")?;
    let (res, dt) = time_once(|| col.serve_until_complete(
        (timeout > 0).then(|| Duration::from_secs(timeout as u64))));
    let paths = res?;
    let summary = merge_segments(&paths, &out)?;
    println!("merged {} segment(s) into {}: {} ids / {} shards, {} payload, \
              {} file ({})",
             paths.len(), out.display(), summary.ids, summary.shards,
             fmt_bytes(summary.payload_bytes), fmt_bytes(summary.file_bytes),
             fmt_s(dt));
    let ref_path = args.get("reference");
    if ref_path.is_empty() {
        return Ok(0);
    }
    let reference = StoreReader::open(Path::new(ref_path))?;
    let candidate = StoreReader::open(&out)?;
    let tolerance = Tolerance::new().safety(args.get_f64("safety")?);
    let rep = Report::check_readers(&reference, &candidate, &tolerance)?;
    println!("{}", rep.render(args.get_usize("rows")?));
    Ok(rep.exit_code())
}

/// §5.2 threshold estimation for externally recorded runs: per id, the
/// larger of the perturbation response (base vs perturbed) and the rerun
/// noise floor (base vs rerun — zero for a bit-deterministic trainer).
/// Writes base's trace with the estimates and run meta embedded, so the
/// output is a drop-in `check-offline` / `collect --reference` store.
fn estimate_cmd(argv: &[String]) -> Result<i32> {
    let cli = Cli::new("derive §5.2 per-tensor threshold estimates from \
                        three recorded stores and write a reference store \
                        with the estimates embedded")
        .pos("base.ttrc", "the reference run")
        .pos("rerun.ttrc", "a second, identically configured reference run")
        .pos("perturbed.ttrc", "the same config recorded with \
                                `record --perturb EPS`")
        .opt("eps", "0", "machine epsilon the check thresholds are derived \
                          at (0 = the bf16 default; use the --perturb \
                          magnitude of the third run)")
        .req("out", "write base's trace + estimates + run meta here");
    let args = cli.parse_from(argv)?;
    let base = StoreReader::open(Path::new(args.pos(0)))?;
    let rerun = StoreReader::open(Path::new(args.pos(1)))?;
    let perturbed = StoreReader::open(Path::new(args.pos(2)))?;
    let base_trace = store_trace(&base)?;
    let rel = Session::estimate_thresholds(&base_trace,
                                           &store_trace(&rerun)?,
                                           &store_trace(&perturbed)?)?;
    let eps = match args.get_f64("eps")? {
        e if e > 0.0 => e,
        _ => CheckCfg::default().eps,
    };
    let out = args.get("out");
    let mut w = StoreWriter::create(Path::new(out))?;
    w.set_estimate(&rel, eps);
    if let Some(meta) = base.run_meta() {
        w.set_run_meta(meta);
    }
    write_trace(&base_trace, &mut w)?;
    let summary = w.finish()?;
    println!("estimated thresholds for {} tensor(s) at eps {eps:.3e}; \
              wrote reference store {out}: {} ids / {} shards, {} file",
             rel.len(), summary.ids, summary.shards,
             fmt_bytes(summary.file_bytes));
    let mut worst: Vec<(&String, &f64)> = rel.iter().collect();
    worst.sort_by(|a, b| b.1.total_cmp(a.1));
    for (k, v) in worst.iter().take(5) {
        println!("  {k:<52} {v:.3e}");
    }
    Ok(0)
}

/// Materialize a whole store as an in-memory trace (the estimate recipe
/// compares full traces, not stores).
fn store_trace(reader: &StoreReader) -> Result<Trace> {
    let mut t = Trace::default();
    for key in reader.keys() {
        t.entries.insert(key.clone(), reader.read_entries(key)?
            .expect("key came from the store index"));
    }
    Ok(t)
}

fn train(argv: &[String]) -> Result<i32> {
    let cli = parcfg_cli(Cli::new("train and print the loss curve"))
        .opt("steps", "10", "training iterations")
        .opt("bug", "0", "inject Table-1 bug number (0 = none)");
    let args = cli.parse_from(argv)?;
    let (m, mut p, layers) = parse_parcfg(&args)?;
    let bug_no = args.get_usize("bug")?;
    let bugs = if bug_no == 0 {
        BugSet::none()
    } else {
        let bug = find_bug(bug_no)?;
        bug.arm_parcfg(&mut p);
        BugSet::one(bug)
    };
    let exec = Executor::load(ttrace::default_artifacts_dir())?;
    let data = data_source(args.get("data"), m.v)?;
    let engine = Engine::new(m, p.clone(), layers, &exec, bugs)?;
    println!("training '{}' ({} layers, ~{:.1}M params) on {}",
             m.name, layers, m.param_count(layers) as f64 / 1e6,
             p.topo.describe());
    let steps = args.get_usize("steps")? as u64;
    let (losses, dt) = time_once(|| {
        mean_losses(&run_training(&engine, data.as_ref(), &NoopHooks, steps))
    });
    for (i, l) in losses.iter().enumerate() {
        println!("step {i:>4}  loss {l:.4}");
    }
    println!("{} steps in {} ({} / step)", steps, fmt_s(dt),
             fmt_s(dt / steps as f64));
    // per-module profile (the §Perf instrument)
    let st = exec.stats();
    let mut mods: Vec<(&String, &(u64, f64))> = st.per_module.iter().collect();
    mods.sort_by(|a, b| b.1 .1.partial_cmp(&a.1 .1).unwrap());
    println!("\nruntime: {} execs, compile {}, execute {}, marshal {}",
             st.executions, fmt_s(st.compile_s), fmt_s(st.execute_s),
             fmt_s(st.marshal_s));
    println!("top modules by device time:");
    for (k, (n, t)) in mods.iter().take(10) {
        println!("  {:<40} {:>6} execs  {:>10}  ({} avg)",
                 k, n, fmt_s(*t), fmt_s(*t / *n as f64));
    }
    Ok(0)
}

fn bugs() -> Result<i32> {
    println!("{:<4} {:<4} {:<5} {:<7} {:<42} {}", "ID", "New", "Type",
             "Static", "Description", "Impact");
    for b in BugId::all() {
        let i = b.info();
        println!("{:<4} {:<4} {:<5} {:<7} {:<42} {}", i.number,
                 if i.new { "yes" } else { "" }, i.btype.name(),
                 if i.expect_static { "lint" } else { "" },
                 i.description, i.impact);
    }
    Ok(0)
}
