//! Hand-rolled substrates: the offline vendored crate set contains only
//! `xla` + `anyhow`, so the JSON codec, CLI parser, RNG, bf16 rounding,
//! property-test harness and bench harness all live here.

pub mod bench;
pub mod bf16;
pub mod cli;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
