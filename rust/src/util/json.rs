//! Minimal JSON substrate (the vendored offline crate set has no serde).
//!
//! Implements exactly what the repo needs: parsing the artifact
//! `manifest.json`, serializing TTrace reports/traces, and round-tripping
//! config files. Numbers are f64 (JSON semantics); integer accessors
//! convert with range checks.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -----------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, v: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v);
        } else {
            panic!("set() on non-object");
        }
        self
    }

    pub fn from_f64(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn from_usize(v: usize) -> Json {
        Json::Num(v as f64)
    }

    pub fn from_str_(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    // ---- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            // non-finite doubles serialize as 16-digit bit-pattern strings
            Json::Str(s) => {
                if let Some(hex) = s.strip_prefix("0x") {
                    if hex.len() == 16 {
                        if let Ok(bits) = u64::from_str_radix(hex, 16) {
                            return Ok(f64::from_bits(bits));
                        }
                    }
                }
                bail!("not a number: {self:?}")
            }
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > u32::MAX as f64 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    // ---- serialization -----------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_nan() || n.is_infinite() {
                    // JSON has no non-finite literals; emit the bit pattern
                    // as a string (as_f64 reads it back exactly)
                    let _ = write!(out, "\"0x{:016x}\"", n.to_bits());
                } else if *n == 0.0 && n.is_sign_negative() {
                    out.push_str("-0.0"); // the i64 path would drop the sign
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    item.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        for _ in 0..indent + 1 {
                            out.push_str(" ");
                        }
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    for _ in 0..indent {
                        out.push_str(" ");
                    }
                }
                out.push('}');
            }
        }
    }

    // ---- parsing -----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected '{}' at byte {}, found '{}'", b as char, self.pos,
                  self.peek()? as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                }
                _ => {
                    // Continue a UTF-8 sequence byte-by-byte.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'",
                           self.pos, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'",
                           self.pos, c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("3.25e2").unwrap().as_f64().unwrap(), 325.0);
        assert_eq!(Json::parse("-7").unwrap().as_i64().unwrap(), -7);
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café \t ok""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café \t ok");
        let v2 = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v2.as_str().unwrap(), "héllo→");
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"k": [1, 2, 3]}"#).unwrap();
        let arr = v.req("k").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn non_finite_and_negative_zero_roundtrip() {
        // -0.0 keeps its sign through text
        let z = Json::Num(-0.0);
        assert_eq!(z.to_string_compact(), "-0.0");
        let back = Json::parse(&z.to_string_compact()).unwrap();
        assert!(back.as_f64().unwrap().is_sign_negative());
        // non-finite values become bit-pattern strings and read back exactly
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let j = Json::Num(v);
            let text = j.to_string_compact();
            let got = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(got.to_bits(), v.to_bits(), "{text}");
        }
    }

    #[test]
    fn compact_is_parseable() {
        let mut o = Json::obj();
        o.set("x", Json::from_usize(3))
            .set("s", Json::from_str_("a\"b"));
        let re = Json::parse(&o.to_string_compact()).unwrap();
        assert_eq!(re.req("x").unwrap().as_usize().unwrap(), 3);
        assert_eq!(re.req("s").unwrap().as_str().unwrap(), "a\"b");
    }
}
