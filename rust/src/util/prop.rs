//! Mini property-testing harness (no proptest in the offline crate set).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! seed so the case is replayable (`PROP_SEED=<n> cargo test ...`) and
//! performs a simple "shrink" over the case index. The generation RNG is
//! `util::rng::Rng`, so cases are platform-stable.

use super::rng::Rng;

/// Number of cases per property (override with env PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` seeded RNGs; panic with the failing seed.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, mut prop: F) {
    let base: u64 = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_0000);
    let cases = default_cases();
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {i}/{cases} \
                 (replay: PROP_SEED={seed} PROP_CASES=1): {msg}"
            );
        }
    }
}

/// Helpers for building random cases.
pub struct Gen;

impl Gen {
    /// Random usize in [lo, hi] inclusive.
    pub fn range(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u64) as usize
    }

    /// Random power of two in [lo, hi] (both powers of two).
    pub fn pow2(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        let lo_e = lo.trailing_zeros();
        let hi_e = hi.trailing_zeros();
        1usize << Self::range(rng, lo_e as usize, hi_e as usize)
    }

    /// Random f32 vector with normal entries.
    pub fn vec_normal(rng: &mut Rng, n: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, std);
        v
    }

    /// Pick one element of a slice.
    pub fn choice<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
        &xs[rng.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, default_cases());
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn failing_property_reports_seed() {
        check("failing", |rng| {
            if rng.below(4) == 0 {
                Err("boom".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn gen_ranges() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let x = Gen::range(&mut rng, 3, 9);
            assert!((3..=9).contains(&x));
            let p = Gen::pow2(&mut rng, 2, 16);
            assert!([2, 4, 8, 16].contains(&p));
        }
    }
}
