//! Wall-clock benchmark harness (no criterion in the offline crate set).
//!
//! Each `benches/*.rs` target uses `harness = false` and drives this:
//! warmup, timed iterations, mean/min/p50 stats, and aligned table output
//! so every bench prints the rows/series of the paper table or figure it
//! regenerates. Results can also be dumped as CSV for plotting.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Stats {
        iters,
        mean_s: mean,
        min_s: samples[0],
        p50_s: samples[samples.len() / 2],
    }
}

/// Time a single run of `f` (for expensive end-to-end benches).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Aligned table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }

    /// Write the table as CSV (for figure reproduction).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut s = self.headers.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, s)
    }
}

/// Human format for seconds.
pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_reports_sane_stats() {
        let st = time(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(st.iters, 5);
        assert!(st.min_s <= st.mean_s);
        assert!(st.min_s > 0.0);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "x".into()]);
        t.print();
        let p = std::env::temp_dir().join("ttrace_bench_test.csv");
        t.write_csv(p.to_str().unwrap()).unwrap();
        let got = std::fs::read_to_string(&p).unwrap();
        assert_eq!(got, "a,b\n1,x\n");
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_s(2.0).ends_with('s'));
        assert!(fmt_s(0.002).ends_with("ms"));
        assert!(fmt_s(2e-6).ends_with("µs"));
    }
}
