//! Wall-clock benchmark harness (no criterion in the offline crate set).
//!
//! Each `benches/*.rs` target uses `harness = false` and drives this:
//! warmup, timed iterations, mean/min/p50 stats, and aligned table output
//! so every bench prints the rows/series of the paper table or figure it
//! regenerates. Results can also be dumped as CSV for plotting.
//!
//! ## Perf trajectory (`BENCH_<name>.json`)
//!
//! Every bench also records per-stage wall clock through [`BenchJson`] and
//! writes `BENCH_<name>.json` (into `$BENCH_JSON_DIR`, default the working
//! directory). `make bench-smoke` runs all benches in short mode
//! (`BENCH_SMOKE=1`, see [`smoke`]) and CI uploads the JSON files as
//! artifacts, so kernel/checker perf is tracked per-PR.

use std::path::PathBuf;
use std::time::Instant;

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Stats {
        iters,
        mean_s: mean,
        min_s: samples[0],
        p50_s: samples[samples.len() / 2],
    }
}

/// Time a single run of `f` (for expensive end-to-end benches).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Aligned table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }

    /// Write the table as CSV (for figure reproduction).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut s = self.headers.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, s)
    }
}

/// True when the bench should run its short mode (`BENCH_SMOKE=1`) — a few
/// seconds per bench, enough to seed the perf trajectory without the full
/// figure-quality sweep.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Pick `full` normally, `short` under `BENCH_SMOKE=1` (env overrides via
/// the bench-specific variable still win — call this only for defaults).
pub fn smoke_or(full: usize, short: usize) -> usize {
    if smoke() { short } else { full }
}

/// Per-stage wall-clock recorder; serializes to `BENCH_<name>.json`.
pub struct BenchJson {
    name: String,
    stages: Vec<(String, f64)>,
    threads: usize,
}

impl BenchJson {
    pub fn new(name: &str) -> BenchJson {
        BenchJson {
            name: name.to_string(),
            stages: Vec::new(),
            threads: crate::util::par::threads(),
        }
    }

    /// Record a stage that was timed externally.
    pub fn stage(&mut self, label: &str, seconds: f64) {
        self.stages.push((label.to_string(), seconds));
    }

    /// Time `f` and record it as `label`.
    pub fn time_stage<T>(&mut self, label: &str, f: impl FnOnce() -> T) -> T {
        let (out, dt) = time_once(f);
        self.stage(label, dt);
        out
    }

    /// Write `BENCH_<name>.json` into `$BENCH_JSON_DIR` (default: the
    /// working directory) and return the path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
        self.write_in(std::path::Path::new(&dir))
    }

    /// Write `BENCH_<name>.json` into an explicit directory.
    pub fn write_in(&self, dir: &std::path::Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut stages = Vec::new();
        let mut total = 0.0f64;
        for (label, s) in &self.stages {
            let mut o = Json::obj();
            o.set("label", Json::from_str_(label));
            o.set("s", Json::from_f64(*s));
            stages.push(o);
            total += s;
        }
        let mut root = Json::obj();
        root.set("name", Json::from_str_(&self.name));
        root.set("smoke", Json::Bool(smoke()));
        root.set("threads", Json::from_usize(self.threads));
        root.set("total_s", Json::from_f64(total));
        root.set("stages", Json::Arr(stages));
        std::fs::write(&path, root.to_string_pretty())?;
        eprintln!("bench trajectory: wrote {}", path.display());
        Ok(path)
    }
}

/// Human format for byte counts.
pub fn fmt_bytes(n: u64) -> String {
    const KIB: f64 = 1024.0;
    let f = n as f64;
    if f < KIB {
        format!("{n}B")
    } else if f < KIB * KIB {
        format!("{:.1}KiB", f / KIB)
    } else if f < KIB * KIB * KIB {
        format!("{:.1}MiB", f / (KIB * KIB))
    } else {
        format!("{:.2}GiB", f / (KIB * KIB * KIB))
    }
}

/// Human format for seconds.
pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_reports_sane_stats() {
        let st = time(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(st.iters, 5);
        assert!(st.min_s <= st.mean_s);
        assert!(st.min_s > 0.0);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "x".into()]);
        t.print();
        let p = std::env::temp_dir().join("ttrace_bench_test.csv");
        t.write_csv(p.to_str().unwrap()).unwrap();
        let got = std::fs::read_to_string(&p).unwrap();
        assert_eq!(got, "a,b\n1,x\n");
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_s(2.0).ends_with('s'));
        assert!(fmt_s(0.002).ends_with("ms"));
        assert!(fmt_s(2e-6).ends_with("µs"));
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert!(fmt_bytes(3 * 1024 * 1024).ends_with("MiB"));
    }

    #[test]
    fn bench_json_roundtrip() {
        // write_in (not the env var): mutating the process environment from
        // a test races other threads' getenv
        let dir = std::env::temp_dir().join("ttrace_bench_json_test");
        let mut b = BenchJson::new("unit");
        b.stage("warm", 0.25);
        let v = b.time_stage("work", || 7usize);
        assert_eq!(v, 7);
        let path = b.write_in(&dir).unwrap();
        assert_eq!(path.file_name().unwrap().to_str().unwrap(), "BENCH_unit.json");
        let j = Json::parse_file(&path).unwrap();
        assert_eq!(j.req("name").unwrap().as_str().unwrap(), "unit");
        let stages = j.req("stages").unwrap().as_arr().unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].req("label").unwrap().as_str().unwrap(), "warm");
        assert!(j.req("total_s").unwrap().as_f64().unwrap() >= 0.25);
    }
}
