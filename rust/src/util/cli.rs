//! Declarative CLI flag parser (no clap in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands, with generated `--help` text. Used by the `ttrace`
//! binary and the examples.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug)]
pub struct Opt {
    pub name: &'static str,
    pub default: Option<&'static str>,
    pub help: &'static str,
    pub is_flag: bool,
}

/// A required positional argument (e.g. `check-offline <ref> <cand>`).
#[derive(Clone, Debug)]
pub struct PosOpt {
    pub name: &'static str,
    pub help: &'static str,
}

#[derive(Default)]
pub struct Cli {
    pub about: &'static str,
    opts: Vec<Opt>,
    pos: Vec<PosOpt>,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(about: &'static str) -> Self {
        Cli { about, opts: Vec::new(), pos: Vec::new() }
    }

    /// Register `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &'static str,
               help: &'static str) -> Self {
        self.opts.push(Opt { name, default: Some(default), help, is_flag: false });
        self
    }

    /// Register a required `--name <value>` (no default).
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, default: None, help, is_flag: false });
        self
    }

    /// Register a boolean `--name` flag (default false).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, default: None, help, is_flag: true });
        self
    }

    /// Register a required positional argument. Registration order is the
    /// command-line order; commands with registered positionals reject a
    /// wrong argument count at parse time.
    pub fn pos(mut self, name: &'static str, help: &'static str) -> Self {
        self.pos.push(PosOpt { name, help });
        self
    }

    pub fn usage(&self, prog: &str) -> String {
        let pos_usage: String =
            self.pos.iter().map(|p| format!(" <{}>", p.name)).collect();
        let mut s = format!("{}\n\nUSAGE: {prog} [OPTIONS]{pos_usage}\n",
                            self.about);
        if !self.pos.is_empty() {
            s.push_str("\nARGS:\n");
            for p in &self.pos {
                s.push_str(&format!("{:<42} {}\n", format!("  <{}>", p.name),
                                    p.help));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else if let Some(d) = o.default {
                format!("  --{} <v> [default: {d}]", o.name)
            } else {
                format!("  --{} <v> (required)", o.name)
            };
            s.push_str(&format!("{head:<42} {}\n", o.help));
        }
        s
    }

    /// Parse an explicit argv slice (excluding the program name).
    pub fn parse_from(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        for o in &self.opts {
            if o.is_flag {
                args.flags.insert(o.name.to_string(), false);
            } else if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage("<prog>"));
            }
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline_val) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| anyhow!("unknown option --{name}\n{}",
                                           self.usage("<prog>")))?;
                if opt.is_flag {
                    if inline_val.is_some() {
                        bail!("--{name} takes no value");
                    }
                    args.flags.insert(name.to_string(), true);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow!("--{name} needs a value"))?
                        }
                    };
                    args.values.insert(name.to_string(), v);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if !o.is_flag && !args.values.contains_key(o.name) {
                bail!("missing required --{}\n{}", o.name, self.usage("<prog>"));
            }
        }
        if !self.pos.is_empty() && args.positional.len() != self.pos.len() {
            let names: Vec<String> =
                self.pos.iter().map(|p| format!("<{}>", p.name)).collect();
            bail!("expected {} positional argument(s): {} (got {})\n{}",
                  self.pos.len(), names.join(" "), args.positional.len(),
                  self.usage("<prog>"));
        }
        Ok(args)
    }

    /// Parse the process arguments.
    pub fn parse(&self) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        self.parse_from(&argv)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} was not registered"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} was not registered"))
    }

    /// The i-th positional argument (in-bounds after a parse that
    /// registered positionals).
    pub fn pos(&self, i: usize) -> &str {
        &self.positional[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let cli = Cli::new("t").opt("size", "4", "").flag("verbose", "");
        let a = cli.parse_from(&v(&["--size", "8"])).unwrap();
        assert_eq!(a.get_usize("size").unwrap(), 8);
        assert!(!a.flag("verbose"));
        let b = cli.parse_from(&v(&["--verbose"])).unwrap();
        assert_eq!(b.get_usize("size").unwrap(), 4);
        assert!(b.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_positional() {
        let cli = Cli::new("t").opt("mode", "x", "");
        let a = cli.parse_from(&v(&["--mode=y", "pos1", "pos2"])).unwrap();
        assert_eq!(a.get("mode"), "y");
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn required_and_unknown() {
        let cli = Cli::new("t").req("must", "");
        assert!(cli.parse_from(&v(&[])).is_err());
        assert!(cli.parse_from(&v(&["--nope", "1"])).is_err());
        assert!(cli.parse_from(&v(&["--must", "1"])).is_ok());
    }

    #[test]
    fn registered_positionals_check_arity() {
        let cli = Cli::new("t").pos("ref", "reference file")
                               .pos("cand", "candidate file")
                               .opt("mode", "x", "");
        assert!(cli.parse_from(&v(&["a"])).is_err());
        assert!(cli.parse_from(&v(&["a", "b", "c"])).is_err());
        let a = cli.parse_from(&v(&["a", "--mode=y", "b"])).unwrap();
        assert_eq!(a.pos(0), "a");
        assert_eq!(a.pos(1), "b");
        assert_eq!(a.get("mode"), "y");
        assert!(cli.usage("p").contains("<ref> <cand>"));
    }
}
