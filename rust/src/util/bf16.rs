//! BF16 semantics on the host side.
//!
//! The training recipe is BF16 mixed precision: device-side tensors are
//! bfloat16, so any host-side arithmetic the coordinator performs on
//! activations/parameters (residual adds, collective reductions, bias adds)
//! must round through bf16 to match what a bf16 device kernel would
//! produce. These helpers implement IEEE round-to-nearest-even f32→bf16.

/// Machine epsilon of bfloat16: 7 explicit mantissa bits → ε = 2^-7
/// (numpy's `finfo(bfloat16).eps` convention). This is the paper's ε_mch:
/// thresholds and figure axes are expressed in multiples of it. The maximum
/// relative rounding error (unit roundoff) is ε/2 = 2^-8.
pub const EPS_BF16: f32 = 0.0078125; // 2^-7

/// Machine epsilon of f32 (2^-23), same convention.
pub const EPS_F32: f32 = 1.1920929e-7;

/// Machine epsilon of float8 e4m3 (3 explicit mantissa bits → 2^-3).
pub const EPS_E4M3: f32 = 0.125;

/// Round an f32 to the nearest bf16 (round-to-nearest-even), returned as f32.
#[inline]
pub fn round_bf16(x: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(x))
}

#[inline]
fn round_bf16_bits(bits: u32) -> u16 {
    // NaN must stay NaN: force a quiet NaN payload.
    if (bits & 0x7FFF_FFFF) > 0x7F80_0000 {
        return ((bits >> 16) as u16) | 0x0040;
    }
    // Round to nearest even on the truncated 16 bits.
    let lsb = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7FFF + lsb)) >> 16) as u16
}

/// f32 -> bf16 bit pattern (u16), round-to-nearest-even.
#[inline]
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    round_bf16_bits(x.to_bits())
}

/// bf16 bit pattern -> f32 (exact).
#[inline]
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round every element of a slice through bf16 in place.
pub fn round_slice_bf16(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = bf16_bits_to_f32(f32_to_bf16_bits(*x));
    }
}

/// Pack f32 slice into bf16 bit patterns (for building device literals).
pub fn pack_bf16(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| f32_to_bf16_bits(x)).collect()
}

/// Unpack bf16 bit patterns into f32s (exact).
pub fn unpack_bf16(bits: &[u16]) -> Vec<f32> {
    bits.iter().map(|&b| bf16_bits_to_f32(b)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_values() {
        // Values with <= 8 significand bits are exactly representable.
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 3.0, 0.00390625, -30000.0] {
            let r = bf16_bits_to_f32(f32_to_bf16_bits(v));
            // -30000 is NOT exactly representable; skip exactness for it.
            if v != -30000.0 {
                assert_eq!(r, v, "value {v}");
            }
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + eps/2 rounds back to 1.0 (ties-to-even).
        let x = 1.0f32 + EPS_BF16 / 2.0;
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(x)), 1.0);
        // 1.0 + 1.5*eps rounds up to 1.0 + 2*eps (tie, even mantissa).
        let y = 1.0f32 + 1.5 * EPS_BF16;
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(y)), 1.0 + 2.0 * EPS_BF16);
    }

    #[test]
    fn relative_error_bounded() {
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..1000 {
            let x = (rng.normal() as f32) * 100.0;
            if x == 0.0 {
                continue;
            }
            let r = bf16_bits_to_f32(f32_to_bf16_bits(x));
            assert!(((r - x) / x).abs() <= EPS_BF16 / 2.0 + 1e-7);
        }
    }

    #[test]
    fn nan_and_inf() {
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn pack_unpack() {
        let xs = vec![0.1f32, -2.5, 7.0, 1e-3];
        let packed = pack_bf16(&xs);
        let un = unpack_bf16(&packed);
        for (a, b) in xs.iter().zip(un.iter()) {
            assert!(((a - b) / a).abs() <= EPS_BF16);
        }
    }
}
