//! Deterministic scoped parallelism (no rayon in the offline crate set).
//!
//! Everything here preserves bit-determinism by construction: work items
//! are statically assigned to workers (round-robin by item index) and every
//! item owns a disjoint slice of the output, so results are independent of
//! scheduling and of the worker count. The *only* thing the thread count
//! may change is wall-clock time — `rust/tests/determinism.rs` asserts
//! exactly that.
//!
//! The worker count is resolved once from `TTRACE_THREADS` (default: the
//! machine's available parallelism) and can be overridden at runtime with
//! `set_threads` (tests use this to prove thread-count invariance).

use std::sync::atomic::{AtomicUsize, Ordering};

/// 0 = not yet resolved (re-reads the environment on next `threads()`).
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// The worker count for parallel regions (>= 1).
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let n = std::env::var("TTRACE_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    THREADS.store(n, Ordering::Relaxed);
    n
}

/// Override the worker count; `0` resets to the environment default.
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// Simulated SPMD ranks currently executing (`dist::run_spmd` maintains
/// this). Parallel regions divide their width by it so nested
/// rank-level + kernel-level parallelism doesn't oversubscribe the CPU.
static ACTIVE_RANKS: AtomicUsize = AtomicUsize::new(0);

pub fn enter_ranks(n: usize) {
    ACTIVE_RANKS.fetch_add(n, Ordering::Relaxed);
}

pub fn exit_ranks(n: usize) {
    ACTIVE_RANKS.fetch_sub(n, Ordering::Relaxed);
}

/// The worker count a parallel region should actually use right now: the
/// configured width divided by the number of live SPMD ranks (each rank is
/// already a thread). Never changes results — only how wide the fan-out is.
pub fn effective_threads() -> usize {
    let ranks = ACTIVE_RANKS.load(Ordering::Relaxed).max(1);
    (threads() / ranks).max(1)
}

/// Serializes tests that sweep the global worker count — the setting is
/// process-global, so concurrent sweeps would shrink each other's coverage.
#[cfg(test)]
pub(crate) static TEST_THREADS_LOCK: std::sync::Mutex<()> =
    std::sync::Mutex::new(());

/// Run `f(index, item)` for every item, fanning the items across up to
/// `threads()` scoped workers. Items are assigned round-robin by index, so
/// the item->worker mapping is static; `f` must only write state owned by
/// its item (e.g. a `chunks_mut` slice), which makes the result identical
/// for every worker count.
pub fn par_items<I, T, F>(items: I, f: F)
where
    I: Iterator<Item = T>,
    T: Send,
    F: Fn(usize, T) + Sync,
{
    let t = effective_threads();
    if t <= 1 {
        for (i, item) in items.enumerate() {
            f(i, item);
        }
        return;
    }
    let mut buckets: Vec<Vec<(usize, T)>> = (0..t).map(|_| Vec::new()).collect();
    for (i, item) in items.enumerate() {
        buckets[i % t].push((i, item));
    }
    // Nothing to fan out (0 or 1 item): run inline, skip the spawn cost.
    if buckets[1..].iter().all(|b| b.is_empty()) {
        for (i, item) in buckets.remove(0) {
            f(i, item);
        }
        return;
    }
    let f = &f;
    std::thread::scope(|s| {
        let mut rest = buckets.split_off(1);
        for bucket in rest.drain(..) {
            if bucket.is_empty() {
                continue;
            }
            s.spawn(move || {
                for (i, item) in bucket {
                    f(i, item);
                }
            });
        }
        // worker 0 runs on the calling thread
        for (i, item) in buckets.remove(0) {
            f(i, item);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_items_covers_every_index_once() {
        let mut out = vec![0u32; 103];
        par_items(out.chunks_mut(7), |i, chunk| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (i * 7 + j) as u32 + 1;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u32 + 1, "index {i}");
        }
    }

    #[test]
    fn results_identical_across_worker_counts() {
        let _guard = TEST_THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let run = |t: usize| -> Vec<f32> {
            set_threads(t);
            let mut out = vec![0.0f32; 64];
            par_items(out.chunks_mut(5), |i, chunk| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = ((i * 5 + j) as f32).sin();
                }
            });
            out
        };
        let a = run(1);
        let b = run(4);
        set_threads(0);
        let ab: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ab, bb);
    }

    #[test]
    fn empty_and_single_item_run_inline() {
        par_items(std::iter::empty::<usize>(), |_, _| panic!("no items"));
        let mut hits = vec![0usize; 1];
        par_items(hits.chunks_mut(1), |i, c| c[0] = i + 41);
        assert_eq!(hits[0], 41);
    }
}
