//! Deterministic RNG substrate.
//!
//! Everything TTrace does hinges on *consistent* randomness: the candidate
//! (distributed) and reference (single-device) runs must draw bit-identical
//! logical tensors (§4.2 of the paper, "consistent distributed tensor
//! generator"). We therefore use a self-contained SplitMix64 generator
//! seeded from a stable 64-bit hash of the tensor's canonical identifier —
//! no global state, no thread-ordering sensitivity.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes, and trivially
/// reproducible across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Seed from a string (e.g. a canonical tensor identifier).
    pub fn from_name(name: &str) -> Self {
        Rng::new(fnv1a(name.as_bytes()))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Modulo bias is irrelevant at our n << 2^64.
        self.next_u64() % n.max(1)
    }

    /// Standard normal via Box-Muller (uses one pair per call; we do not
    /// cache the second variate so the stream position is predictable).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a buffer with N(0, std^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = (self.normal() as f32) * std;
        }
    }

    /// Fill with uniform integers in [0, n) as f32 (token ids etc.).
    pub fn fill_ints(&mut self, out: &mut [i32], n: u64) {
        for v in out.iter_mut() {
            *v = self.below(n) as i32;
        }
    }
}

/// FNV-1a 64-bit offset basis — the hash state before any byte.
pub const FNV_OFFSET_BASIS: u64 = 0xcbf29ce484222325;

/// FNV-1a 64-bit hash — stable across runs/platforms, used to derive RNG
/// seeds from canonical tensor identifiers.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_update(FNV_OFFSET_BASIS, bytes)
}

/// Incremental FNV-1a step: fold `bytes` into an existing hash state
/// (seed with [`FNV_OFFSET_BASIS`]). Chunked hashing of a stream equals
/// one-shot hashing of the concatenation — the `.ttrc` store checksums
/// files this way.
pub fn fnv1a_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn name_seeding_differs() {
        assert_ne!(Rng::from_name("a").next_u64(), Rng::from_name("b").next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 20000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn fnv_stability() {
        // Pinned value: the seed derivation is part of the trace format.
        assert_eq!(fnv1a(b"ttrace"), fnv1a(b"ttrace"));
        assert_ne!(fnv1a(b"ttrace"), fnv1a(b"ttracf"));
    }

    #[test]
    fn fnv_chunked_equals_one_shot() {
        let data = b"the .ttrc checksum is computed in 64KiB chunks";
        let mut h = FNV_OFFSET_BASIS;
        for chunk in data.chunks(7) {
            h = fnv1a_update(h, chunk);
        }
        assert_eq!(h, fnv1a(data));
    }
}
