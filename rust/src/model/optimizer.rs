//! Mixed-precision Adam with an optional ZeRO-1 distributed optimizer.
//!
//! Master weights and moments are f32 on the host; after each step the
//! bf16 model copy is refreshed. Under ZeRO-1, optimizer states are
//! partitioned over the dp×cp group by round-robin parameter ownership:
//! the owner updates, then broadcasts the new master weights. Bug 9 skips
//! the broadcast (silent "no parameter update" on non-owners); bug 5 (in
//! `finalize_grads`) breaks the embedding/LM-head tie under ZeRO.

use crate::dist::RankCtx;
use crate::ttrace::hooks::{CanonId, Hooks, Kind};

use super::engine::{Engine, RankState};
use crate::bugs::BugId;

const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

impl<'a> Engine<'a> {
    pub(crate) fn optimizer_step(&self, ctx: &RankCtx, st: &mut RankState,
                                 hooks: &dyn Hooks, iter: u64) {
        st.adam_t += 1;
        let t = st.adam_t as i32;
        let bc1 = 1.0 - BETA1.powi(t);
        let bc2 = 1.0 - BETA2.powi(t);
        let dpcp = ctx.dpcp_group();
        let zero1 = self.p.zero1 && dpcp.size > 1;

        for (idx, name) in st.params.order.clone().iter().enumerate() {
            let owner = idx % dpcp.size;
            let i_own = !zero1 || owner == dpcp.me;
            if i_own {
                let p = st.params.get_mut(name);
                for i in 0..p.master.data.len() {
                    let g = p.main_grad.data[i];
                    p.m.data[i] = BETA1 * p.m.data[i] + (1.0 - BETA1) * g;
                    p.v.data[i] = BETA2 * p.v.data[i] + (1.0 - BETA2) * g * g;
                    let mhat = p.m.data[i] / bc1;
                    let vhat = p.v.data[i] / bc2;
                    p.master.data[i] -= self.lr * mhat / (vhat.sqrt() + ADAM_EPS);
                }
            }
            if zero1 && !self.bugs.on(BugId::B9ZeroUpdateFailure) {
                // ZeRO-1: owner broadcasts the updated master weights
                let master = st.params.get(name).master.clone();
                let updated = ctx.comm.broadcast(&dpcp.key, dpcp.me, dpcp.size,
                                                 owner, &master);
                st.params.get_mut(name).master = updated;
            }
            let p = st.params.get_mut(name);
            p.refresh_model();
            hooks.record(&CanonId::new(iter, 0, Kind::Param, name), &p.model,
                         &p.spec.clone());
        }
    }
}
