//! Parameter definition, initialization and per-rank sharding.
//!
//! Parameters are defined once, by canonical name, with their *global*
//! (reference) shapes and a rule for how each parallel layout shards them.
//! Initialization draws the logical full tensor from the consistent
//! generator (`ttrace::gen`) seeded by the parameter name, then slices the
//! rank's shard — so candidate shards are bit-identical slices of the
//! reference parameters (paper §4.2).
//!
//! Mixed-precision bookkeeping per parameter:
//!   master   f32 (updated by Adam)
//!   model    bf16 (fed to device modules; rounded from master)
//!   main_grad f32 (accumulated across microbatches; reduced over dp×cp)

use std::collections::HashMap;

use crate::dist::Coord;
use crate::tensor::{DType, Tensor};
use crate::ttrace::gen;
use crate::ttrace::shard::ShardSpec;

use super::config::{ModelCfg, ParCfg};

/// How a parameter's gradients must be synchronized beyond the dp×cp
/// main-grad reduction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradSync {
    /// sharded over tp — dp×cp reduction only
    Sharded,
    /// replicated over tp, inputs replicated — grads already identical
    Replicated,
    /// replicated over tp but computed from tp-sharded (sequence-parallel)
    /// inputs — REQUIRES a tp all-reduce (LN params under SP, router under
    /// SP; bugs #6/#12/#14 live here)
    ReplicatedSeqSharded,
}

#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub spec: ShardSpec,
    pub sync: GradSync,
    pub master: Tensor,
    pub model: Tensor,
    pub main_grad: Tensor,
    /// Adam moments
    pub m: Tensor,
    pub v: Tensor,
}

impl Param {
    fn new(name: String, spec: ShardSpec, sync: GradSync, init: Tensor) -> Param {
        let local = spec.extract_local(&init);
        let master = Tensor::new(&local.dims, local.data.clone(), DType::F32);
        let model = local.round_bf16();
        let zeros = Tensor::zeros(&local.dims, DType::F32);
        Param {
            name,
            spec,
            sync,
            master,
            model,
            main_grad: zeros.clone(),
            m: zeros.clone(),
            v: zeros,
        }
    }

    pub fn zero_grad(&mut self) {
        self.main_grad.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Accumulate a bf16 per-microbatch gradient into the f32 main grad.
    pub fn accumulate(&mut self, grad: &Tensor) {
        assert_eq!(grad.dims, self.main_grad.dims,
                   "grad shape mismatch for {}", self.name);
        for (a, g) in self.main_grad.data.iter_mut().zip(&grad.data) {
            *a += g;
        }
    }

    /// Refresh the bf16 model copy from the master weights.
    pub fn refresh_model(&mut self) {
        self.model = self.master.round_bf16();
    }
}

/// The full per-rank parameter set, keyed by canonical name, plus the
/// deterministic name order (used by ZeRO ownership assignment).
pub struct ParamSet {
    pub params: HashMap<String, Param>,
    pub order: Vec<String>,
}

impl ParamSet {
    pub fn get(&self, name: &str) -> &Param {
        self.params
            .get(name)
            .unwrap_or_else(|| panic!("unknown param '{name}'"))
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Param {
        self.params
            .get_mut(name)
            .unwrap_or_else(|| panic!("unknown param '{name}'"))
    }

    pub fn model(&self, name: &str) -> &Tensor {
        &self.get(name).model
    }
}

/// GPT-2 style init: N(0, 0.02) for weights, output projections scaled by
/// 1/sqrt(2L), ones for LN weight, zeros for biases/LN bias.
const INIT_STD: f32 = 0.02;

/// Declarative parameter table for the dense/MoE GPT model.
/// `layer_range` is the global layer ids this rank's stage owns.
pub fn build(m: &ModelCfg, p: &ParCfg, coord: Coord, layers: usize,
             layer_range: &[usize], holds_embedding: bool,
             holds_lmhead: bool) -> ParamSet {
    let tp = p.topo.tp;
    let tpi = coord.tp;
    let d = m.d;
    let resid_std = INIT_STD / ((2.0 * layers as f32).sqrt());

    let mut params: Vec<Param> = Vec::new();

    if holds_embedding || holds_lmhead {
        // Tied word embeddings: held by the first stage (embedding) and the
        // last stage (LM head); grads are synchronized between them.
        let name = "embedding.word_embeddings.weight".to_string();
        let spec = ShardSpec::split(&[m.v, d], 0, tpi, tp);
        let init = gen::full_normal(&name, &[m.v, d], INIT_STD, DType::Bf16);
        params.push(Param::new(name, spec, GradSync::Sharded, init));
    }

    for &l in layer_range {
        let pre = format!("layers.{l}");
        let ln_sync = if p.sp { GradSync::ReplicatedSeqSharded } else { GradSync::Replicated };

        for ln in ["input_layernorm", "pre_mlp_layernorm"] {
            let wname = format!("{pre}.{ln}.weight");
            params.push(Param::new(
                wname,
                ShardSpec::full(&[d]),
                ln_sync,
                gen::full_const(&[d], 1.0, DType::Bf16),
            ));
            let bname = format!("{pre}.{ln}.bias");
            params.push(Param::new(
                bname,
                ShardSpec::full(&[d]),
                ln_sync,
                gen::full_const(&[d], 0.0, DType::Bf16),
            ));
        }

        // fused QKV (column-parallel; shard owns matching head-slices of
        // each of the Q/K/V thirds)
        let wname = format!("{pre}.self_attention.linear_qkv.weight");
        let wspec = ShardSpec::full(&[d, 3 * d]).and_qkv_split(1, d, tpi, tp);
        let winit = gen::full_normal(&wname, &[d, 3 * d], INIT_STD, DType::Bf16);
        params.push(Param::new(wname, wspec, GradSync::Sharded, winit));
        let bname = format!("{pre}.self_attention.linear_qkv.bias");
        let bspec = ShardSpec::full(&[3 * d]).and_qkv_split(0, d, tpi, tp);
        params.push(Param::new(bname, bspec, GradSync::Sharded,
                               gen::full_const(&[3 * d], 0.0, DType::Bf16)));

        // output projection (row-parallel: input dim sharded)
        let wname = format!("{pre}.self_attention.linear_proj.weight");
        let wspec = ShardSpec::split(&[d, d], 0, tpi, tp);
        let winit = gen::full_normal(&wname, &[d, d], resid_std, DType::Bf16);
        params.push(Param::new(wname, wspec, GradSync::Sharded, winit));
        // proj bias is added after the (reduce-scattered) output under SP,
        // so each tp rank sees a different sequence shard -> same sync rule
        // as the LN params.
        let bname = format!("{pre}.self_attention.linear_proj.bias");
        params.push(Param::new(bname, ShardSpec::full(&[d]), ln_sync,
                               gen::full_const(&[d], 0.0, DType::Bf16)));

        if p.moe {
            let rname = format!("{pre}.mlp.router.weight");
            let rsync = if p.sp { GradSync::ReplicatedSeqSharded } else { GradSync::Replicated };
            let rinit = gen::full_normal(&rname, &[d, m.e], INIT_STD, DType::Bf16);
            params.push(Param::new(rname, ShardSpec::full(&[d, m.e]), rsync, rinit));

            let w1name = format!("{pre}.mlp.experts.fc1.weight");
            let w1spec = ShardSpec::split(&[m.e, d, m.f], 2, tpi, tp);
            let w1init = gen::full_normal(&w1name, &[m.e, d, m.f], INIT_STD, DType::Bf16);
            params.push(Param::new(w1name, w1spec, GradSync::Sharded, w1init));
            let b1name = format!("{pre}.mlp.experts.fc1.bias");
            let b1spec = ShardSpec::split(&[m.e, m.f], 1, tpi, tp);
            params.push(Param::new(b1name, b1spec, GradSync::Sharded,
                                   gen::full_const(&[m.e, m.f], 0.0, DType::Bf16)));
            let w2name = format!("{pre}.mlp.experts.fc2.weight");
            let w2spec = ShardSpec::split(&[m.e, m.f, d], 1, tpi, tp);
            let w2init = gen::full_normal(&w2name, &[m.e, m.f, d], resid_std, DType::Bf16);
            params.push(Param::new(w2name, w2spec, GradSync::Sharded, w2init));
        } else {
            let w1name = format!("{pre}.mlp.fc1.weight");
            let w1spec = ShardSpec::split(&[d, m.f], 1, tpi, tp);
            let w1init = gen::full_normal(&w1name, &[d, m.f], INIT_STD, DType::Bf16);
            params.push(Param::new(w1name, w1spec, GradSync::Sharded, w1init));
            let b1name = format!("{pre}.mlp.fc1.bias");
            let b1spec = ShardSpec::split(&[m.f], 0, tpi, tp);
            params.push(Param::new(b1name, b1spec, GradSync::Sharded,
                                   gen::full_const(&[m.f], 0.0, DType::Bf16)));
            let w2name = format!("{pre}.mlp.fc2.weight");
            let w2spec = ShardSpec::split(&[m.f, d], 0, tpi, tp);
            let w2init = gen::full_normal(&w2name, &[m.f, d], resid_std, DType::Bf16);
            params.push(Param::new(w2name, w2spec, GradSync::Sharded, w2init));
        }
    }

    if holds_lmhead {
        let sync = if p.sp { GradSync::ReplicatedSeqSharded } else { GradSync::Replicated };
        params.push(Param::new("final_layernorm.weight".to_string(),
                               ShardSpec::full(&[d]), sync,
                               gen::full_const(&[d], 1.0, DType::Bf16)));
        params.push(Param::new("final_layernorm.bias".to_string(),
                               ShardSpec::full(&[d]), sync,
                               gen::full_const(&[d], 0.0, DType::Bf16)));
    }

    let order: Vec<String> = params.iter().map(|p| p.name.clone()).collect();
    let map = params.into_iter().map(|p| (p.name.clone(), p)).collect();
    ParamSet { params: map, order }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Topology;
    use crate::model::config::TINY;

    fn coord0() -> Coord {
        Coord { dp: 0, tp: 0, pp: 0, cp: 0 }
    }

    #[test]
    fn single_device_full_params() {
        let p = ParCfg::single();
        let set = build(&TINY, &p, coord0(), 2, &[0, 1], true, true);
        let emb = set.get("embedding.word_embeddings.weight");
        assert_eq!(emb.master.dims, vec![64, 32]);
        assert!(emb.spec.is_full());
        // embedding + final_ln(w,b) + per layer: 2 LN pairs(4) + qkv(2) +
        // proj(2) + fc1(2) + fc2(1) = 11
        assert_eq!(set.order.len(), 3 + 2 * 11);
    }

    #[test]
    fn tp_shards_are_slices_of_reference() {
        let mut p = ParCfg::single();
        p.topo = Topology::new(1, 2, 1, 1, 1).unwrap();
        let pref = ParCfg::single();
        let ref_set = build(&TINY, &pref, coord0(), 2, &[0, 1], true, true);
        for tpi in 0..2 {
            let c = Coord { dp: 0, tp: tpi, pp: 0, cp: 0 };
            let set = build(&TINY, &p, c, 2, &[0, 1], true, true);
            for name in &set.order {
                let shard = set.get(name);
                let full = ref_set.get(name);
                let expect = shard.spec.extract_local(&full.master);
                assert_eq!(shard.master.data, expect.data, "{name} tp{tpi}");
            }
        }
    }

    #[test]
    fn qkv_shard_covers_qkv_thirds() {
        let mut p = ParCfg::single();
        p.topo = Topology::new(1, 2, 1, 1, 1).unwrap();
        let c = Coord { dp: 0, tp: 1, pp: 0, cp: 0 };
        let set = build(&TINY, &p, c, 2, &[0], true, true);
        let qkv = set.get("layers.0.self_attention.linear_qkv.weight");
        assert_eq!(qkv.master.dims, vec![32, 48]); // [D, 3*D/2]
        let pieces = &qkv.spec.maps[0].pieces;
        assert_eq!(pieces.len(), 3);
        // rank 1 of 2: starts at D/2, D + D/2, 2D + D/2
        assert_eq!(pieces[0].global_start, 16);
        assert_eq!(pieces[1].global_start, 48);
        assert_eq!(pieces[2].global_start, 80);
    }

    #[test]
    fn ln_sync_rule_depends_on_sp() {
        let mut p = ParCfg::single();
        p.topo = Topology::new(1, 2, 1, 1, 1).unwrap();
        let set = build(&TINY, &p, coord0(), 2, &[0], true, true);
        assert_eq!(set.get("layers.0.input_layernorm.weight").sync,
                   GradSync::Replicated);
        p.sp = true;
        let set2 = build(&TINY, &p, coord0(), 2, &[0], true, true);
        assert_eq!(set2.get("layers.0.input_layernorm.weight").sync,
                   GradSync::ReplicatedSeqSharded);
    }
}
