//! Parameter definition, initialization and per-rank sharding.
//!
//! Parameters are defined once, by canonical name, with their *global*
//! (reference) shapes and a rule for how each parallel layout shards them.
//! Initialization draws the logical full tensor from the consistent
//! generator (`ttrace::gen`) seeded by the parameter name, then slices the
//! rank's shard — so candidate shards are bit-identical slices of the
//! reference parameters (paper §4.2).
//!
//! Mixed-precision bookkeeping per parameter:
//!   master   f32 (updated by Adam)
//!   model    bf16 (fed to device modules; rounded from master)
//!   main_grad f32 (accumulated across microbatches; reduced over dp×cp)

use std::collections::HashMap;

use crate::dist::Coord;
use crate::tensor::{DType, Tensor};
use crate::ttrace::gen;
use crate::ttrace::shard::ShardSpec;

use super::config::{ModelCfg, ParCfg};

/// How a parameter's gradients must be synchronized beyond the dp×cp
/// main-grad reduction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradSync {
    /// sharded over tp — dp×cp reduction only
    Sharded,
    /// replicated over tp, inputs replicated — grads already identical
    Replicated,
    /// replicated over tp but computed from tp-sharded (sequence-parallel)
    /// inputs — REQUIRES a tp all-reduce (LN params under SP, router under
    /// SP; bugs #6/#12/#14 live here)
    ReplicatedSeqSharded,
}

#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub spec: ShardSpec,
    pub sync: GradSync,
    pub master: Tensor,
    pub model: Tensor,
    pub main_grad: Tensor,
    /// Adam moments
    pub m: Tensor,
    pub v: Tensor,
}

impl Param {
    fn new(name: String, spec: ShardSpec, sync: GradSync, init: Tensor) -> Param {
        let local = spec.extract_local(&init);
        let master = Tensor::new(&local.dims, local.data.clone(), DType::F32);
        let model = local.round_bf16();
        let zeros = Tensor::zeros(&local.dims, DType::F32);
        Param {
            name,
            spec,
            sync,
            master,
            model,
            main_grad: zeros.clone(),
            m: zeros.clone(),
            v: zeros,
        }
    }

    pub fn zero_grad(&mut self) {
        self.main_grad.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Accumulate a bf16 per-microbatch gradient into the f32 main grad.
    pub fn accumulate(&mut self, grad: &Tensor) {
        assert_eq!(grad.dims, self.main_grad.dims,
                   "grad shape mismatch for {}", self.name);
        for (a, g) in self.main_grad.data.iter_mut().zip(&grad.data) {
            *a += g;
        }
    }

    /// Refresh the bf16 model copy from the master weights.
    pub fn refresh_model(&mut self) {
        self.model = self.master.round_bf16();
    }
}

/// The full per-rank parameter set, keyed by canonical name, plus the
/// deterministic name order (used by ZeRO ownership assignment).
pub struct ParamSet {
    pub params: HashMap<String, Param>,
    pub order: Vec<String>,
}

impl ParamSet {
    pub fn get(&self, name: &str) -> &Param {
        self.params
            .get(name)
            .unwrap_or_else(|| panic!("unknown param '{name}'"))
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Param {
        self.params
            .get_mut(name)
            .unwrap_or_else(|| panic!("unknown param '{name}'"))
    }

    pub fn model(&self, name: &str) -> &Tensor {
        &self.get(name).model
    }
}

/// GPT-2 style init: N(0, 0.02) for weights, output projections scaled by
/// 1/sqrt(2L), ones for LN weight, zeros for biases/LN bias.
const INIT_STD: f32 = 0.02;

/// How a parameter's logical full tensor is initialized (`build` resolves
/// this through the consistent generator; `ttrace::analyze` ignores it).
#[derive(Clone, Copy, Debug)]
enum InitRule {
    /// N(0, std) seeded by the parameter name
    Normal(f32),
    /// constant fill (LN weights, biases)
    Const(f32),
}

/// One row of the declarative parameter table: everything knowable about a
/// parameter *without allocating it* — canonical name, this rank's
/// `ShardSpec` into the reference tensor, and the grad-sync rule.
#[derive(Clone, Debug)]
pub struct ParamDecl {
    pub name: String,
    pub spec: ShardSpec,
    pub sync: GradSync,
    init: InitRule,
}

/// Declarative parameter table for the dense/MoE GPT model — the single
/// source of truth for parameter names, shard specs and sync rules, shared
/// by `build` (which allocates tensors from it) and the static analyzer
/// (`ttrace::analyze`, which only needs the schema).
/// `layer_range` is the global layer ids this rank's stage owns.
pub fn decls(m: &ModelCfg, p: &ParCfg, coord: Coord, layers: usize,
             layer_range: &[usize], holds_embedding: bool,
             holds_lmhead: bool) -> Vec<ParamDecl> {
    let tp = p.topo.tp;
    let tpi = coord.tp;
    let d = m.d;
    let resid_std = INIT_STD / ((2.0 * layers as f32).sqrt());

    let mut out: Vec<ParamDecl> = Vec::new();
    let mut push = |name: String, spec: ShardSpec, sync: GradSync, init: InitRule| {
        out.push(ParamDecl { name, spec, sync, init });
    };

    if holds_embedding || holds_lmhead {
        // Tied word embeddings: held by the first stage (embedding) and the
        // last stage (LM head); grads are synchronized between them.
        push("embedding.word_embeddings.weight".to_string(),
             ShardSpec::split(&[m.v, d], 0, tpi, tp),
             GradSync::Sharded, InitRule::Normal(INIT_STD));
    }

    for &l in layer_range {
        let pre = format!("layers.{l}");
        let ln_sync = if p.sp { GradSync::ReplicatedSeqSharded } else { GradSync::Replicated };

        for ln in ["input_layernorm", "pre_mlp_layernorm"] {
            push(format!("{pre}.{ln}.weight"), ShardSpec::full(&[d]),
                 ln_sync, InitRule::Const(1.0));
            push(format!("{pre}.{ln}.bias"), ShardSpec::full(&[d]),
                 ln_sync, InitRule::Const(0.0));
        }

        // fused QKV (column-parallel; shard owns matching head-slices of
        // each of the Q/K/V thirds)
        push(format!("{pre}.self_attention.linear_qkv.weight"),
             ShardSpec::full(&[d, 3 * d]).and_qkv_split(1, d, tpi, tp),
             GradSync::Sharded, InitRule::Normal(INIT_STD));
        push(format!("{pre}.self_attention.linear_qkv.bias"),
             ShardSpec::full(&[3 * d]).and_qkv_split(0, d, tpi, tp),
             GradSync::Sharded, InitRule::Const(0.0));

        // output projection (row-parallel: input dim sharded)
        push(format!("{pre}.self_attention.linear_proj.weight"),
             ShardSpec::split(&[d, d], 0, tpi, tp),
             GradSync::Sharded, InitRule::Normal(resid_std));
        // proj bias is added after the (reduce-scattered) output under SP,
        // so each tp rank sees a different sequence shard -> same sync rule
        // as the LN params.
        push(format!("{pre}.self_attention.linear_proj.bias"),
             ShardSpec::full(&[d]), ln_sync, InitRule::Const(0.0));

        if p.moe {
            let rsync = if p.sp { GradSync::ReplicatedSeqSharded } else { GradSync::Replicated };
            push(format!("{pre}.mlp.router.weight"),
                 ShardSpec::full(&[d, m.e]), rsync, InitRule::Normal(INIT_STD));
            push(format!("{pre}.mlp.experts.fc1.weight"),
                 ShardSpec::split(&[m.e, d, m.f], 2, tpi, tp),
                 GradSync::Sharded, InitRule::Normal(INIT_STD));
            push(format!("{pre}.mlp.experts.fc1.bias"),
                 ShardSpec::split(&[m.e, m.f], 1, tpi, tp),
                 GradSync::Sharded, InitRule::Const(0.0));
            push(format!("{pre}.mlp.experts.fc2.weight"),
                 ShardSpec::split(&[m.e, m.f, d], 1, tpi, tp),
                 GradSync::Sharded, InitRule::Normal(resid_std));
        } else {
            push(format!("{pre}.mlp.fc1.weight"),
                 ShardSpec::split(&[d, m.f], 1, tpi, tp),
                 GradSync::Sharded, InitRule::Normal(INIT_STD));
            push(format!("{pre}.mlp.fc1.bias"),
                 ShardSpec::split(&[m.f], 0, tpi, tp),
                 GradSync::Sharded, InitRule::Const(0.0));
            push(format!("{pre}.mlp.fc2.weight"),
                 ShardSpec::split(&[m.f, d], 0, tpi, tp),
                 GradSync::Sharded, InitRule::Normal(resid_std));
        }
    }

    if holds_lmhead {
        let sync = if p.sp { GradSync::ReplicatedSeqSharded } else { GradSync::Replicated };
        push("final_layernorm.weight".to_string(), ShardSpec::full(&[d]),
             sync, InitRule::Const(1.0));
        push("final_layernorm.bias".to_string(), ShardSpec::full(&[d]),
             sync, InitRule::Const(0.0));
    }

    out
}

/// Allocate the per-rank parameter set from the declarative table.
/// Initialization draws each logical full tensor from the consistent
/// generator and slices the rank's shard.
pub fn build(m: &ModelCfg, p: &ParCfg, coord: Coord, layers: usize,
             layer_range: &[usize], holds_embedding: bool,
             holds_lmhead: bool) -> ParamSet {
    let table = decls(m, p, coord, layers, layer_range, holds_embedding,
                      holds_lmhead);
    let mut params: Vec<Param> = Vec::with_capacity(table.len());
    for decl in table {
        let init = match decl.init {
            InitRule::Normal(std) =>
                gen::full_normal(&decl.name, &decl.spec.global_dims, std,
                                 DType::Bf16),
            InitRule::Const(v) =>
                gen::full_const(&decl.spec.global_dims, v, DType::Bf16),
        };
        params.push(Param::new(decl.name, decl.spec, decl.sync, init));
    }

    let order: Vec<String> = params.iter().map(|p| p.name.clone()).collect();
    let map = params.into_iter().map(|p| (p.name.clone(), p)).collect();
    ParamSet { params: map, order }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Topology;
    use crate::model::config::TINY;

    fn coord0() -> Coord {
        Coord { dp: 0, tp: 0, pp: 0, cp: 0 }
    }

    #[test]
    fn single_device_full_params() {
        let p = ParCfg::single();
        let set = build(&TINY, &p, coord0(), 2, &[0, 1], true, true);
        let emb = set.get("embedding.word_embeddings.weight");
        assert_eq!(emb.master.dims, vec![64, 32]);
        assert!(emb.spec.is_full());
        // embedding + final_ln(w,b) + per layer: 2 LN pairs(4) + qkv(2) +
        // proj(2) + fc1(2) + fc2(1) = 11
        assert_eq!(set.order.len(), 3 + 2 * 11);
    }

    #[test]
    fn tp_shards_are_slices_of_reference() {
        let mut p = ParCfg::single();
        p.topo = Topology::new(1, 2, 1, 1, 1).unwrap();
        let pref = ParCfg::single();
        let ref_set = build(&TINY, &pref, coord0(), 2, &[0, 1], true, true);
        for tpi in 0..2 {
            let c = Coord { dp: 0, tp: tpi, pp: 0, cp: 0 };
            let set = build(&TINY, &p, c, 2, &[0, 1], true, true);
            for name in &set.order {
                let shard = set.get(name);
                let full = ref_set.get(name);
                let expect = shard.spec.extract_local(&full.master);
                assert_eq!(shard.master.data, expect.data, "{name} tp{tpi}");
            }
        }
    }

    #[test]
    fn qkv_shard_covers_qkv_thirds() {
        let mut p = ParCfg::single();
        p.topo = Topology::new(1, 2, 1, 1, 1).unwrap();
        let c = Coord { dp: 0, tp: 1, pp: 0, cp: 0 };
        let set = build(&TINY, &p, c, 2, &[0], true, true);
        let qkv = set.get("layers.0.self_attention.linear_qkv.weight");
        assert_eq!(qkv.master.dims, vec![32, 48]); // [D, 3*D/2]
        let pieces = &qkv.spec.maps[0].pieces;
        assert_eq!(pieces.len(), 3);
        // rank 1 of 2: starts at D/2, D + D/2, 2D + D/2
        assert_eq!(pieces[0].global_start, 16);
        assert_eq!(pieces[1].global_start, 48);
        assert_eq!(pieces[2].global_start, 80);
    }

    #[test]
    fn ln_sync_rule_depends_on_sp() {
        let mut p = ParCfg::single();
        p.topo = Topology::new(1, 2, 1, 1, 1).unwrap();
        let set = build(&TINY, &p, coord0(), 2, &[0], true, true);
        assert_eq!(set.get("layers.0.input_layernorm.weight").sync,
                   GradSync::Replicated);
        p.sp = true;
        let set2 = build(&TINY, &p, coord0(), 2, &[0], true, true);
        assert_eq!(set2.get("layers.0.input_layernorm.weight").sync,
                   GradSync::ReplicatedSeqSharded);
    }
}
