//! Forward bodies of the engine: embedding path, transformer layer, LM
//! head + vocab-parallel cross-entropy. Every traced tensor is recorded
//! through the `Hooks` surface with its `ShardSpec`; every module input
//! offers a rewrite point (paper §4.3).

use crate::bugs::BugId;
use crate::dist::RankCtx;
use crate::tensor::{DType, Tensor};
use crate::ttrace::canonical::names;
use crate::ttrace::hooks::{CanonId, Hooks, Kind};

use super::engine::{Engine, HeadTape, LayerInner, LayerTape, RankState};
use super::params::ParamSet;
use super::seq;

impl<'a> Engine<'a> {
    /// Embedding forward: masked vocab-sharded lookup + tp reduction.
    /// Returns the residual-domain activation [B, t_sp, D].
    pub(crate) fn embed_fwd_path(&self, ctx: &RankCtx, st: &RankState,
                                 hooks: &dyn Hooks, iter: u64, micro: u32,
                                 tokens: &Tensor) -> Tensor {
        let tp = ctx.tp_group();
        // Bug 1 (TP: wrong embedding mask): the shard's vocab offset is off
        // by one, so the in-shard mask drops one boundary token id per
        // shard and mis-maps another — a *subtle* corruption (a few % of
        // tokens embed wrongly), like the original slapo/Megatron bug: the
        // loss curve barely moves (Figure 1) but the embedding activations
        // diverge far beyond FP round-off.
        let correct = (self.sh.vp * ctx.coord.tp) as i32;
        let offset = if self.bugs.on(BugId::B1TpEmbeddingMask) && tp.size > 1 {
            correct + 1
        } else {
            correct
        };
        let table = st.params.model("embedding.word_embeddings.weight");
        let off = Tensor::scalar(offset as f32, DType::I32);
        let partial = &self.run_mod(&self.keys.embed_fwd,
                                    &[tokens, table, &off])[0];
        let out = if self.p.sp {
            self.rowpar_reduce(ctx, partial)
        } else {
            self.ar_bf16(ctx, &tp, partial)
        };
        self.rec(hooks, iter, micro, Kind::Act, &names::embedding(), &out,
                 self.spec_sp(ctx));
        out
    }

    /// One transformer layer forward. `x` is residual-domain [B, t_sp, D].
    /// When `record` is false this is a recomputation pass (no hooks).
    pub(crate) fn layer_fwd(&self, ctx: &RankCtx, st: &mut RankState,
                            hooks: &dyn Hooks, iter: u64, micro: u32,
                            layer: usize, x: &Tensor, record: bool)
                            -> (Tensor, LayerInner) {
        let h = if record { Some(hooks) } else { None };
        let params = &st.params;
        let mut scales: Vec<f32> = Vec::new();

        let x = x.clone();

        // input layernorm
        let g1 = params.model(&format!("layers.{layer}.input_layernorm.weight"));
        let b1 = params.model(&format!("layers.{layer}.input_layernorm.bias"));
        let ln1_out = self.run_mod(&self.keys.ln_fwd, &[&x, g1, b1]).remove(0);
        if let Some(h) = h {
            self.rec(h, iter, micro, Kind::Act, &names::input_ln(layer),
                     &ln1_out, self.spec_sp(ctx));
        }

        // fused QKV (column-parallel); SP gathers the full local sequence
        let mut qkv_in = self.sp_gather(ctx, &ln1_out);
        let wq = params.model(&format!(
            "layers.{layer}.self_attention.linear_qkv.weight"));
        // Bug 8 (AR + fp8, W-CP): the recompute stash holds the activation
        // pre-quantized to e4m3 — but with the *weight's* scale (a swapped
        // scale slot). The corrupted tensor feeds the forward matmul too:
        // activations are ~50x larger than weights, so the cast clips them
        // hard -> wrong loss, exactly the paper's impact for this bug.
        if self.bugs.on(BugId::B8ArFp8Cast) && self.p.fp8 && self.p.recompute {
            let sw = Self::fp8_scale_e4m3(wq.max_abs());
            qkv_in = qdq_e4m3_host(&qkv_in, sw);
        }
        let bq = params.model(&format!(
            "layers.{layer}.self_attention.linear_qkv.bias"));
        let qkv_out = if self.p.fp8 {
            // Bug 7 (W-CM): the fp8 amax reduction runs over the wrong
            // communication group; the slot this rank reads back is another
            // tensor's amax (the weight's), so the activation scale is off
            // by the activation/weight magnitude ratio and the cast clips.
            let sx = if self.bugs.on(BugId::B7Fp8WrongGroup) {
                Self::fp8_scale_e4m3(self.fp8_amax(ctx, wq))
            } else {
                Self::fp8_scale_e4m3(self.fp8_amax(ctx, &qkv_in))
            };
            let sw = Self::fp8_scale_e4m3(self.fp8_amax(ctx, wq));
            scales.extend([sx, sw]);
            self.run_mod(&self.keys.qkv_fp8_fwd,
                         &[&qkv_in, wq, bq, &Tensor::scalar(sx, DType::F32),
                           &Tensor::scalar(sw, DType::F32)]).remove(0)
        } else {
            self.run_mod(&self.keys.qkv_fwd, &[&qkv_in, wq, bq]).remove(0)
        };
        if let Some(h) = h {
            self.rec(h, iter, micro, Kind::Act, &names::qkv(layer), &qkv_out,
                     self.spec_qkv(ctx));
        }

        // core attention (pallas kernel) with cp-gathered K/V
        let (q, k, v) = self.split_heads(&qkv_out);
        let k_full = self.cp_gather_kv(ctx, &k);
        let v_full = self.cp_gather_kv(ctx, &v);
        let positions = seq::seq_positions(self.sh.s, self.p.topo.cp, ctx.coord.cp);
        let mask = seq::causal_mask(&positions, self.sh.s);
        let attn_heads = self.run_mod(&self.keys.attn_fwd,
                                      &[&q, &k_full, &v_full, &mask]).remove(0);
        let attn_out = attn_heads.permute(&[0, 2, 1, 3])
            .reshape(&[self.sh.b, self.sh.t_cp, self.sh.dp]);
        if let Some(h) = h {
            self.rec(h, iter, micro, Kind::Act, &names::core_attn(layer),
                     &attn_out, self.spec_cp(ctx, self.sh.d, true));
        }

        // output projection (row-parallel) + bias after the reduction
        let wp = params.model(&format!(
            "layers.{layer}.self_attention.linear_proj.weight"));
        let bp = params.model(&format!(
            "layers.{layer}.self_attention.linear_proj.bias"));
        let proj_partial = if self.p.fp8 {
            let sx = Self::fp8_scale_e4m3(self.fp8_amax(ctx, &attn_out));
            let sw = Self::fp8_scale_e4m3(self.fp8_amax(ctx, wp));
            scales.extend([sx, sw]);
            self.run_mod(&self.keys.proj_fp8_fwd,
                         &[&attn_out, wp, &Tensor::scalar(sx, DType::F32),
                           &Tensor::scalar(sw, DType::F32)]).remove(0)
        } else {
            self.run_mod(&self.keys.proj_fwd, &[&attn_out, wp]).remove(0)
        };
        let proj_red = self.rowpar_reduce(ctx, &proj_partial);
        let proj_out = seq::add_bias_bf16(&proj_red, bp);
        if let Some(h) = h {
            self.rec(h, iter, micro, Kind::Act, &names::proj(layer), &proj_out,
                     self.spec_sp(ctx));
        }

        let resid1 = x.add_bf16(&proj_out);

        // pre-MLP layernorm
        let g2 = params.model(&format!("layers.{layer}.pre_mlp_layernorm.weight"));
        let b2 = params.model(&format!("layers.{layer}.pre_mlp_layernorm.bias"));
        let ln2_out = self.run_mod(&self.keys.ln_fwd, &[&resid1, g2, b2]).remove(0);
        if let Some(h) = h {
            self.rec(h, iter, micro, Kind::Act, &names::pre_mlp_ln(layer),
                     &ln2_out, self.spec_sp(ctx));
        }

        // MLP (dense or MoE), column/row parallel
        let mlp_in = self.sp_gather(ctx, &ln2_out);
        let (mlp_partial, combine_full) = if self.p.moe {
            let wr = params.model(&format!("layers.{layer}.mlp.router.weight"));
            // router runs on the SP-sharded sequence (ln2_out)
            let combine_local = self.run_mod(&self.keys.router_fwd,
                                             &[&ln2_out, wr]).remove(0);
            if let Some(h) = h {
                self.rec(h, iter, micro, Kind::Act, &names::router(layer),
                         &combine_local,
                         self.spec_router(ctx));
            }
            let combine_full = self.sp_gather(ctx, &combine_local);
            let w1 = params.model(&format!("layers.{layer}.mlp.experts.fc1.weight"));
            let b1e = params.model(&format!("layers.{layer}.mlp.experts.fc1.bias"));
            let w2 = params.model(&format!("layers.{layer}.mlp.experts.fc2.weight"));
            let y = self.run_mod(&self.keys.experts_fwd,
                                 &[&mlp_in, w1, b1e, w2, &combine_full]).remove(0);
            (y, Some(combine_full))
        } else {
            let w1 = params.model(&format!("layers.{layer}.mlp.fc1.weight"));
            let b1m = params.model(&format!("layers.{layer}.mlp.fc1.bias"));
            let w2 = params.model(&format!("layers.{layer}.mlp.fc2.weight"));
            if self.p.fp8 {
                let sx = Self::fp8_scale_e4m3(self.fp8_amax(ctx, &mlp_in));
                let sw1 = Self::fp8_scale_e4m3(self.fp8_amax(ctx, w1));
                // the post-gelu activation is internal to the fused module:
                // delayed scaling from the previous iteration's amax
                let sh_key = format!("layers.{layer}.mlp.h");
                let sh_scale = *st.fp8_sh.get(&sh_key).unwrap_or(&1.0);
                let sw2 = Self::fp8_scale_e4m3(self.fp8_amax(ctx, w2));
                scales.extend([sx, sw1, sh_scale, sw2]);
                let mut outs = self.run_mod(
                    &self.keys.mlp_fp8_fwd,
                    &[&mlp_in, w1, b1m, w2,
                      &Tensor::scalar(sx, DType::F32),
                      &Tensor::scalar(sw1, DType::F32),
                      &Tensor::scalar(sh_scale, DType::F32),
                      &Tensor::scalar(sw2, DType::F32)]);
                let amax_a = outs.remove(1).data[0];
                if record {
                    st.fp8_sh.insert(sh_key,
                                     Self::fp8_scale_e4m3(amax_a));
                }
                (outs.remove(0), None)
            } else {
                (self.run_mod(&self.keys.mlp_fwd,
                              &[&mlp_in, w1, b1m, w2]).remove(0), None)
            }
        };
        let mlp_out = self.rowpar_reduce(ctx, &mlp_partial);
        if let Some(h) = h {
            self.rec(h, iter, micro, Kind::Act, &names::mlp(layer), &mlp_out,
                     self.spec_sp(ctx));
        }

        let out = resid1.add_bf16(&mlp_out);
        if let Some(h) = h {
            self.rec(h, iter, micro, Kind::Act, &names::layer_out(layer), &out,
                     self.spec_sp(ctx));
        }

        let inner = LayerInner {
            qkv_in, q, k_full, v_full, mask, attn_out, resid1,
            ln2_out, mlp_in, combine_full, scales,
        };
        (out, inner)
    }

    /// Run a chunk of layers forward, building tapes. Rewrite points are
    /// offered at every layer input.
    pub(crate) fn chunk_fwd(&self, ctx: &RankCtx, st: &mut RankState,
                            hooks: &dyn Hooks, iter: u64, micro: u32,
                            chunk_layers: &[usize], mut x: Tensor)
                            -> (Tensor, Vec<LayerTape>) {
        let mut tapes = Vec::with_capacity(chunk_layers.len());
        for &layer in chunk_layers {
            let rid = CanonId::new(iter, micro, Kind::Act,
                                   format!("layers.{layer}.input"));
            if let Some(repl) = hooks.rewrite_input(&rid, &self.spec_sp(ctx), &x) {
                x = repl;
            }
            let (out, inner) = self.layer_fwd(ctx, st, hooks, iter, micro,
                                              layer, &x, true);
            tapes.push(LayerTape {
                layer,
                x: x.clone(),
                out: out.clone(),
                inner: if self.p.recompute { None } else { Some(inner) },
            });
            x = out;
        }
        (x, tapes)
    }

    /// Final layernorm + LM head + vocab-parallel cross-entropy.
    /// Returns (mean local loss, HeadTape).
    pub(crate) fn head_fwd(&self, ctx: &RankCtx, st: &RankState,
                           hooks: &dyn Hooks, iter: u64, micro: u32,
                           resid: Tensor, targets: &Tensor) -> (f64, HeadTape) {
        let params: &ParamSet = &st.params;
        let gw = params.model("final_layernorm.weight");
        let gb = params.model("final_layernorm.bias");
        let ln_out = self.run_mod(&self.keys.ln_fwd, &[&resid, gw, gb]).remove(0);
        self.rec(hooks, iter, micro, Kind::Act, &names::final_ln(), &ln_out,
                 self.spec_sp(ctx));

        let mut x_head = self.sp_gather(ctx, &ln_out);
        let rid = CanonId::new(iter, micro, Kind::Act, "output_layer.input");
        if let Some(repl) = hooks.rewrite_input(
            &rid, &self.spec_cp(ctx, self.sh.d, false), &x_head) {
            x_head = repl;
        }

        let table = params.model("embedding.word_embeddings.weight");
        let logits = self.run_mod(&self.keys.lmhead_fwd,
                                  &[&x_head, table]).remove(0);
        self.rec(hooks, iter, micro, Kind::Act, &names::output_layer(), &logits,
                 self.spec_cp(ctx, self.m.v, true));

        let tpg = ctx.tp_group();
        let offset = Tensor::scalar((self.sh.vp * ctx.coord.tp) as f32, DType::I32);
        let lmax = self.run_mod(&self.keys.logits_max, &[&logits]).remove(0);
        let gmax = self.ar_max(ctx, &tpg, &lmax);
        let mut se_tl = self.run_mod(&self.keys.xent_local,
                                     &[&logits, targets, &offset, &gmax]);
        let tlogit = se_tl.remove(1);
        let sumexp = se_tl.remove(0);
        let gsum = self.ar_f32(ctx, &tpg, &sumexp);
        let tsum = self.ar_f32(ctx, &tpg, &tlogit);

        // per-token loss = log(gsum) - (target_logit - gmax)
        let mut total = 0.0f64;
        for (s, t) in gsum.data.iter().zip(&tsum.data) {
            total += (*s as f64).ln() - *t as f64;
        }
        let mut loss = total / gsum.numel() as f64;
        // each cp rank saw a different sequence chunk: the comparable loss
        // is the cp-group average (equal token counts per rank)
        let cpg = ctx.cp_group();
        if cpg.size > 1 {
            let l = Tensor::scalar(loss as f32, DType::F32);
            let summed = self.ar_f32(ctx, &cpg, &l);
            loss = summed.data[0] as f64 / cpg.size as f64;
        }
        self.rec(hooks, iter, micro, Kind::Loss, "loss",
                 &Tensor::scalar(loss as f32, DType::F32),
                 crate::ttrace::shard::ShardSpec::full(&[]));

        (loss, HeadTape { resid, x_head, targets: targets.clone(),
                          gmax, gsum })
    }

    /// ShardSpec of the router output [B, S, E] (seq sp+cp sharded).
    pub(crate) fn spec_router(&self, ctx: &RankCtx) -> crate::ttrace::shard::ShardSpec {
        let topo = self.p.topo;
        seq::seq_spec(&[self.sh.b, self.sh.s, self.sh.e], 1, ctx.coord.cp,
                      topo.cp, if self.p.sp { ctx.coord.tp } else { 0 },
                      if self.p.sp { topo.tp } else { 1 })
    }
}

/// Host-side e4m3 quantize-dequantize (bug-8 fault path only).
pub(crate) fn qdq_e4m3_host(t: &Tensor, scale: f32) -> Tensor {
    let mut out = t.clone();
    for v in out.data.iter_mut() {
        let x = (*v * scale).clamp(-448.0, 448.0);
        // decompose to e4m3 grid: 3 mantissa bits
        let q = if x == 0.0 {
            0.0
        } else {
            let e = x.abs().log2().floor();
            let step = 2f32.powf(e - 3.0);
            (x / step).round() * step
        };
        *v = crate::util::bf16::round_bf16(q / scale);
    }
    out
}
