//! Backward bodies of the engine: LM-head/cross-entropy backward, layer
//! backward (with optional activation recomputation), embedding backward,
//! and per-microbatch parameter-gradient accumulation.

use crate::bugs::BugId;
use crate::dist::RankCtx;
use crate::tensor::{DType, Tensor};
use crate::ttrace::canonical::names;
use crate::ttrace::hooks::{CanonId, Hooks, Kind};

use super::engine::{Engine, HeadTape, LayerInner, LayerTape, RankState};
use super::seq;

impl<'a> Engine<'a> {
    /// Record a per-microbatch bf16 param grad and accumulate it into the
    /// f32 main grad.
    ///
    /// Recording semantics: under context parallelism every per-micro grad
    /// is a partial sum over the rank's sequence chunk (the merger sums
    /// them); under SP the sequence-sharded replicated params (LN, router,
    /// proj bias) are additionally partial over tp. When tp ranks compute
    /// *identical* grads (replicated params, full-sequence inputs) only tp
    /// rank 0 records a partial entry to avoid double-counting in the sum.
    pub(crate) fn acc_grad(&self, ctx: &RankCtx, st: &mut RankState,
                           hooks: &dyn Hooks, iter: u64, micro: u32,
                           name: &str, grad: Tensor) {
        self.acc_grad_as(ctx, st, hooks, iter, micro, name, name, grad);
    }

    /// Like `acc_grad` but records under a different canonical module name
    /// (the tied LM-head contribution to the embedding grad).
    ///
    /// Takes the gradient by value: after accumulation the buffer moves
    /// into the trace (`record_owned`), so the per-micro ParamGrad entries
    /// — the most numerous trace kind — never clone a tensor.
    pub(crate) fn acc_grad_as(&self, ctx: &RankCtx, st: &mut RankState,
                              hooks: &dyn Hooks, iter: u64, micro: u32,
                              record_as: &str, name: &str, grad: Tensor) {
        use crate::model::params::GradSync;
        let topo = self.p.topo;
        let p = st.params.get_mut(name);
        let seq_sharded_over_tp =
            self.p.sp && topo.tp > 1 && p.sync == GradSync::ReplicatedSeqSharded;
        let partial = topo.cp > 1 || seq_sharded_over_tp;
        let tp_duplicates =
            topo.tp > 1 && p.sync != GradSync::Sharded && !seq_sharded_over_tp;
        let suppress = partial && tp_duplicates && ctx.coord.tp != 0;
        p.accumulate(&grad);
        if !suppress {
            let spec = if partial {
                p.spec.clone().as_partial()
            } else {
                p.spec.clone()
            };
            hooks.record_owned(&CanonId::new(iter, micro, Kind::ParamGrad, record_as),
                               grad, &spec);
        }
    }

    /// The per-token loss-gradient scale. Correct semantics: every token of
    /// the *global* batch contributes 1/(B·S·n_micro·dp) (reference runs
    /// dp·n_micro microbatches with dp=1, giving the identical factor).
    /// Bugs 3/4 drop the cp/dp corrections exactly like the Megatron loss-
    /// scaling bugs did.
    pub(crate) fn loss_scale(&self) -> f32 {
        let base = 1.0
            / (self.sh.b as f32 * self.sh.s as f32 * self.p.n_micro as f32
               * self.p.topo.dp as f32);
        let mut scale = base;
        if self.bugs.on(BugId::B3CpLossScale) && self.p.topo.cp > 1 {
            // wrong: treats each cp shard as if it were the full sequence
            scale *= self.p.topo.cp as f32;
        }
        if self.bugs.on(BugId::B4DpLossScale) && self.p.topo.dp > 1 {
            // wrong: forgets that grads are summed across dp replicas
            scale *= self.p.topo.dp as f32;
        }
        scale
    }

    /// LM-head backward: builds dlogits from the saved global max/sumexp,
    /// backprops through the tied embedding and the final layernorm.
    /// Returns the gradient w.r.t. the residual-domain chunk output.
    pub(crate) fn head_bwd(&self, ctx: &RankCtx, st: &mut RankState,
                           hooks: &dyn Hooks, iter: u64, micro: u32,
                           tape: &HeadTape) -> Tensor {
        let scale_v = self.loss_scale();
        let scale = Tensor::full(&[self.sh.b, self.sh.t_cp], scale_v, DType::F32);
        let offset = Tensor::scalar((self.sh.vp * ctx.coord.tp) as f32, DType::I32);
        let table = st.params.model("embedding.word_embeddings.weight").clone();
        let mut outs = self.run_mod(
            &self.keys.lmhead_bwd,
            &[&tape.x_head, &table, &tape.targets, &offset, &tape.gmax,
              &tape.gsum, &scale]);
        let dtable = outs.remove(1);
        let dx_head = outs.remove(0);
        // tied embedding: the LM-head contribution accumulates into the
        // embedding grad (united on pp=1; synchronized across stages later).
        // Recorded under its own id — the embedding's own ParamGrad entry is
        // the scatter-add from embed_bwd.
        self.acc_grad_as(ctx, st, hooks, iter, micro, "output_layer.weight",
                         "embedding.word_embeddings.weight", dtable);

        // bwd of the sp all-gather before the head: reduce-scatter; the
        // vocab-parallel dx is a partial sum over tp -> all-reduce without sp
        let d_ln_out = if self.p.sp {
            self.sp_scatter_grad(ctx, &dx_head, crate::comm::RedPrec::Bf16)
        } else {
            let g = ctx.tp_group();
            self.ar_bf16(ctx, &g, &dx_head)
        };
        // record the head input-grad after the tp reduction (the
        // pre-reduction tensor is a vocab-shard partial sum)
        self.rec(hooks, iter, micro, Kind::ActGrad, &names::output_layer(),
                 &d_ln_out, self.spec_sp(ctx));

        // final layernorm backward
        let gw = st.params.model("final_layernorm.weight").clone();
        let gb = st.params.model("final_layernorm.bias").clone();
        let mut ln_outs = self.run_mod(&self.keys.ln_bwd,
                                       &[&tape.resid, &gw, &gb, &d_ln_out]);
        let dbeta = ln_outs.remove(2);
        let dgamma = ln_outs.remove(1);
        let dresid = ln_outs.remove(0);
        self.rec(hooks, iter, micro, Kind::ActGrad, &names::final_ln(),
                 &dresid, self.spec_sp(ctx));
        self.acc_grad(ctx, st, hooks, iter, micro, "final_layernorm.weight", dgamma);
        self.acc_grad(ctx, st, hooks, iter, micro, "final_layernorm.bias", dbeta);
        dresid
    }

    /// One transformer layer backward. `dy` is the gradient w.r.t. the
    /// layer output (residual domain). Returns grad w.r.t. the layer input.
    pub(crate) fn layer_bwd(&self, ctx: &RankCtx, st: &mut RankState,
                            hooks: &dyn Hooks, iter: u64, micro: u32,
                            tape: &LayerTape, dy: &Tensor) -> Tensor {
        let layer = tape.layer;
        // rewrite point for the backward input (grad of the layer output)
        let rid = CanonId::new(iter, micro, Kind::ActGrad,
                               format!("layers.{layer}.output"));
        let dy = hooks
            .rewrite_input(&rid, &self.spec_sp(ctx), dy)
            .unwrap_or_else(|| dy.clone());

        // Recomputation: rebuild the intermediate activations now. Bug 2
        // recomputes from the layer *output* (a stale/wrong stash) instead
        // of the input.
        let rebuilt: LayerInner;
        let inner: &LayerInner = match &tape.inner {
            Some(i) => i,
            None => {
                let src = if self.bugs.on(BugId::B2ArWrongInput) {
                    &tape.out
                } else {
                    &tape.x
                };
                let (_, i) = self.layer_fwd(ctx, st, hooks, iter, micro, layer,
                                            src, false);
                rebuilt = i;
                &rebuilt
            }
        };

        let pre = format!("layers.{layer}");

        // ---- MLP branch -------------------------------------------------
        // residual passthrough: d(mlp_out) = dy
        let d_mlp_red = self.rowpar_reduce_bwd(ctx, &dy); // [B,t_cp,D]
        let (dx_mlp_partial, d_router) = if self.p.moe {
            let w1 = st.params.model(&format!("{pre}.mlp.experts.fc1.weight")).clone();
            let b1 = st.params.model(&format!("{pre}.mlp.experts.fc1.bias")).clone();
            let w2 = st.params.model(&format!("{pre}.mlp.experts.fc2.weight")).clone();
            let combine = inner.combine_full.as_ref().unwrap();
            let mut outs = self.run_mod(
                &self.keys.experts_bwd,
                &[&inner.mlp_in, &w1, &b1, &w2, combine, &d_mlp_red]);
            let dcombine = outs.remove(4);
            let dw2 = outs.remove(3);
            let db1 = outs.remove(2);
            let dw1 = outs.remove(1);
            let dx = outs.remove(0);
            self.acc_grad(ctx, st, hooks, iter, micro,
                          &format!("{pre}.mlp.experts.fc1.weight"), dw1);
            self.acc_grad(ctx, st, hooks, iter, micro,
                          &format!("{pre}.mlp.experts.fc1.bias"), db1);
            self.acc_grad(ctx, st, hooks, iter, micro,
                          &format!("{pre}.mlp.experts.fc2.weight"), dw2);
            // bwd of the sp all-gather of combine: reduce-scatter (f32)
            let dcombine_local = if self.p.sp {
                self.sp_scatter_grad(ctx, &dcombine, crate::comm::RedPrec::F32)
            } else {
                dcombine
            };
            let wr = st.params.model(&format!("{pre}.mlp.router.weight")).clone();
            let mut r_outs = self.run_mod(&self.keys.router_bwd,
                                          &[&inner.ln2_out, &wr, &dcombine_local]);
            let dwr = r_outs.remove(1);
            let dxr = r_outs.remove(0);
            self.rec(hooks, iter, micro, Kind::ActGrad, &names::router(layer),
                     &dxr, self.spec_sp(ctx));
            self.acc_grad(ctx, st, hooks, iter, micro,
                          &format!("{pre}.mlp.router.weight"), dwr);
            (dx, Some(dxr))
        } else {
            let w1 = st.params.model(&format!("{pre}.mlp.fc1.weight")).clone();
            let b1 = st.params.model(&format!("{pre}.mlp.fc1.bias")).clone();
            let w2 = st.params.model(&format!("{pre}.mlp.fc2.weight")).clone();
            let (dx, dw1, db1, dw2) = if self.p.fp8 {
                let s = &inner.scales; // [qkv sx,sw, proj sx,sw, mlp sx,sw1,sh,sw2]
                let sdy = Self::fp8_scale_e5m2(self.fp8_amax(ctx, &d_mlp_red));
                let mut outs = self.run_mod(
                    &self.keys.mlp_fp8_bwd,
                    &[&inner.mlp_in, &w1, &b1, &w2,
                      &Tensor::scalar(s[4], DType::F32),
                      &Tensor::scalar(s[5], DType::F32),
                      &Tensor::scalar(s[6], DType::F32),
                      &Tensor::scalar(s[7], DType::F32),
                      &Tensor::scalar(sdy, DType::F32), &d_mlp_red]);
                (outs.remove(0), outs.remove(0), outs.remove(0), outs.remove(0))
            } else {
                let mut outs = self.run_mod(
                    &self.keys.mlp_bwd,
                    &[&inner.mlp_in, &w1, &b1, &w2, &d_mlp_red]);
                (outs.remove(0), outs.remove(0), outs.remove(0), outs.remove(0))
            };
            self.acc_grad(ctx, st, hooks, iter, micro,
                          &format!("{pre}.mlp.fc1.weight"), dw1);
            self.acc_grad(ctx, st, hooks, iter, micro,
                          &format!("{pre}.mlp.fc1.bias"), db1);
            self.acc_grad(ctx, st, hooks, iter, micro,
                          &format!("{pre}.mlp.fc2.weight"), dw2);
            (dx, None)
        };
        // column-parallel dx is a partial sum over tp
        let mut dx_ln2 = self.colpar_dx_reduce(ctx, &dx_mlp_partial);
        if let Some(dxr) = d_router {
            dx_ln2 = dx_ln2.add_bf16(&dxr);
        }
        self.rec(hooks, iter, micro, Kind::ActGrad, &names::mlp(layer), &dx_ln2,
                 self.spec_sp(ctx));

        // pre-MLP layernorm backward
        let g2 = st.params.model(&format!("{pre}.pre_mlp_layernorm.weight")).clone();
        let b2 = st.params.model(&format!("{pre}.pre_mlp_layernorm.bias")).clone();
        let mut ln2_outs = self.run_mod(&self.keys.ln_bwd,
                                        &[&inner.resid1, &g2, &b2, &dx_ln2]);
        let db2 = ln2_outs.remove(2);
        let dg2 = ln2_outs.remove(1);
        let dx_r1 = ln2_outs.remove(0);
        self.rec(hooks, iter, micro, Kind::ActGrad, &names::pre_mlp_ln(layer),
                 &dx_r1, self.spec_sp(ctx));
        self.acc_grad(ctx, st, hooks, iter, micro,
                      &format!("{pre}.pre_mlp_layernorm.weight"), dg2);
        self.acc_grad(ctx, st, hooks, iter, micro,
                      &format!("{pre}.pre_mlp_layernorm.bias"), db2);

        let d_resid1 = dy.add_bf16(&dx_r1);

        // ---- attention branch -------------------------------------------
        // proj bias grad (host, matches the host-side bias add)
        let dbias_proj = seq::bias_grad(&d_resid1);
        self.acc_grad(ctx, st, hooks, iter, micro,
                      &format!("{pre}.self_attention.linear_proj.bias"),
                      dbias_proj);
        let d_proj_partial = self.rowpar_reduce_bwd(ctx, &d_resid1);
        let wp = st.params.model(&format!(
            "{pre}.self_attention.linear_proj.weight")).clone();
        let (d_attn, dwp) = if self.p.fp8 {
            let s = &inner.scales;
            let sdy = Self::fp8_scale_e5m2(self.fp8_amax(ctx, &d_proj_partial));
            let mut outs = self.run_mod(
                &self.keys.proj_fp8_bwd,
                &[&inner.attn_out, &wp, &Tensor::scalar(s[2], DType::F32),
                  &Tensor::scalar(s[3], DType::F32),
                  &Tensor::scalar(sdy, DType::F32), &d_proj_partial]);
            (outs.remove(0), outs.remove(0))
        } else {
            let mut outs = self.run_mod(&self.keys.proj_bwd,
                                        &[&inner.attn_out, &wp, &d_proj_partial]);
            (outs.remove(0), outs.remove(0))
        };
        self.acc_grad(ctx, st, hooks, iter, micro,
                      &format!("{pre}.self_attention.linear_proj.weight"), dwp);
        self.rec(hooks, iter, micro, Kind::ActGrad, &names::proj(layer), &d_attn,
                 self.spec_cp(ctx, self.sh.d, true));

        // core attention backward
        let do_heads = d_attn
            .reshape(&[self.sh.b, self.sh.t_cp, self.sh.hp, self.sh.hd])
            .permute(&[0, 2, 1, 3]);
        let mut a_outs = self.run_mod(
            &self.keys.attn_bwd,
            &[&inner.q, &inner.k_full, &inner.v_full, &inner.mask, &do_heads]);
        let dv_full = a_outs.remove(2);
        let dk_full = a_outs.remove(1);
        let dq = a_outs.remove(0);
        let dk = self.cp_scatter_kv_grad(ctx, &dk_full);
        let dv = self.cp_scatter_kv_grad(ctx, &dv_full);
        let dqkv = self.merge_heads3(&dq, &dk, &dv);
        self.rec(hooks, iter, micro, Kind::ActGrad, &names::core_attn(layer),
                 &dqkv, self.spec_qkv(ctx));

        // fused QKV backward
        let wq = st.params.model(&format!(
            "{pre}.self_attention.linear_qkv.weight")).clone();
        let bq = st.params.model(&format!(
            "{pre}.self_attention.linear_qkv.bias")).clone();
        let (dx_qkv, dwq, dbq) = if self.p.fp8 {
            let s = &inner.scales;
            let sdy = Self::fp8_scale_e5m2(self.fp8_amax(ctx, &dqkv));
            let mut outs = self.run_mod(
                &self.keys.qkv_fp8_bwd,
                &[&inner.qkv_in, &wq, &Tensor::scalar(s[0], DType::F32),
                  &Tensor::scalar(s[1], DType::F32),
                  &Tensor::scalar(sdy, DType::F32), &dqkv]);
            (outs.remove(0), outs.remove(0), outs.remove(0))
        } else {
            let mut outs = self.run_mod(&self.keys.qkv_bwd,
                                        &[&inner.qkv_in, &wq, &bq, &dqkv]);
            (outs.remove(0), outs.remove(0), outs.remove(0))
        };
        self.acc_grad(ctx, st, hooks, iter, micro,
                      &format!("{pre}.self_attention.linear_qkv.weight"), dwq);
        self.acc_grad(ctx, st, hooks, iter, micro,
                      &format!("{pre}.self_attention.linear_qkv.bias"), dbq);
        let dx_ln1 = self.colpar_dx_reduce(ctx, &dx_qkv);
        self.rec(hooks, iter, micro, Kind::ActGrad, &names::qkv(layer), &dx_ln1,
                 self.spec_sp(ctx));

        // input layernorm backward
        let g1 = st.params.model(&format!("{pre}.input_layernorm.weight")).clone();
        let b1 = st.params.model(&format!("{pre}.input_layernorm.bias")).clone();
        let mut ln1_outs = self.run_mod(&self.keys.ln_bwd,
                                        &[&tape.x, &g1, &b1, &dx_ln1]);
        let db1 = ln1_outs.remove(2);
        let dg1 = ln1_outs.remove(1);
        let dx0 = ln1_outs.remove(0);
        self.rec(hooks, iter, micro, Kind::ActGrad, &names::input_ln(layer),
                 &dx0, self.spec_sp(ctx));
        self.acc_grad(ctx, st, hooks, iter, micro,
                      &format!("{pre}.input_layernorm.weight"), dg1);
        self.acc_grad(ctx, st, hooks, iter, micro,
                      &format!("{pre}.input_layernorm.bias"), db1);

        d_resid1.add_bf16(&dx0)
    }

    /// Embedding backward (first stage, first chunk).
    pub(crate) fn embed_bwd_path(&self, ctx: &RankCtx, st: &mut RankState,
                                 hooks: &dyn Hooks, iter: u64, micro: u32,
                                 tokens: &Tensor, d_embed: &Tensor) {
        // bwd of the fwd tp reduction: all-reduce -> identity; SP
        // reduce-scatter -> all-gather
        let d_full = if self.p.sp {
            self.sp_gather(ctx, d_embed)
        } else {
            d_embed.clone()
        };
        self.rec(hooks, iter, micro, Kind::ActGrad, &names::embedding(),
                 &d_full, self.spec_cp(ctx, self.sh.d, false));
        let tp = ctx.tp_group();
        let correct = (self.sh.vp * ctx.coord.tp) as i32;
        // bug 1 corrupts the backward mask identically to the forward
        let offset = if self.bugs.on(BugId::B1TpEmbeddingMask) && tp.size > 1 {
            correct + 1
        } else {
            correct
        };
        let off = Tensor::scalar(offset as f32, DType::I32);
        let table = st.params.model("embedding.word_embeddings.weight").clone();
        let dtable = self.run_mod(&self.keys.embed_bwd,
                                  &[tokens, &table, &off, &d_full]).remove(0);
        self.acc_grad(ctx, st, hooks, iter, micro,
                      "embedding.word_embeddings.weight", dtable);
    }
}
