//! Model and parallelism configuration.
//!
//! The named model presets MUST stay in lock-step with
//! `python/compile/aot.py::CONFIGS` (same dims): the engine recomputes each
//! module's shape parameters and loads the artifact keyed by
//! `manifest::module_key(name, params)`.

use anyhow::{bail, Result};

use crate::dist::Topology;
use crate::runtime::manifest::module_key;

/// Model dimensions. `b` is the microbatch size baked into the artifacts.
#[derive(Clone, Copy, Debug)]
pub struct ModelCfg {
    pub name: &'static str,
    pub b: usize,
    pub s: usize,
    pub d: usize,
    pub h: usize,
    pub f: usize,
    pub v: usize,
    pub e: usize,
    /// default transformer layer count (overridable per run; layers are a
    /// runtime loop, not baked into artifacts)
    pub layers: usize,
}

pub const TINY: ModelCfg = ModelCfg {
    name: "tiny", b: 2, s: 16, d: 32, h: 4, f: 64, v: 64, e: 2, layers: 2,
};

pub const SMALL: ModelCfg = ModelCfg {
    name: "small", b: 2, s: 32, d: 64, h: 4, f: 256, v: 256, e: 2, layers: 4,
};

pub const E2E: ModelCfg = ModelCfg {
    name: "e2e", b: 4, s: 128, d: 256, h: 8, f: 1024, v: 2048, e: 2, layers: 8,
};

pub fn preset(name: &str) -> Result<ModelCfg> {
    Ok(match name {
        "tiny" => TINY,
        "small" => SMALL,
        "e2e" => E2E,
        _ => bail!("unknown model preset '{name}' (tiny|small|e2e)"),
    })
}

impl ModelCfg {
    pub fn head_dim(&self) -> usize {
        self.d / self.h
    }

    /// Approximate parameter count at `layers` layers (tied embeddings).
    pub fn param_count(&self, layers: usize) -> usize {
        let d = self.d;
        self.v * d + layers * (12 * d * d) + 2 * d
    }

    pub fn with_layers(mut self, layers: usize) -> ModelCfg {
        self.layers = layers;
        self
    }
}

/// Pipeline schedule flavor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// all microbatch forwards, then all backwards (flush)
    GPipe,
    /// one-forward-one-backward steady state
    OneF1B,
}

/// Parallel/runtime configuration of a training run.
#[derive(Clone, Debug)]
pub struct ParCfg {
    pub topo: Topology,
    /// sequence parallelism (shards LN/residual domain across tp)
    pub sp: bool,
    pub n_micro: usize,
    pub schedule: Schedule,
    /// activation recomputation (store layer inputs only, recompute in bwd)
    pub recompute: bool,
    /// FP8 (e4m3 emulated) linear layers
    pub fp8: bool,
    /// dense top-1 MoE MLPs instead of dense MLPs
    pub moe: bool,
    /// ZeRO-1 distributed optimizer over the dp×cp group
    pub zero1: bool,
    /// overlap grad communication with compute (bug #11's habitat; the
    /// simulation keeps semantics identical unless the bug is armed)
    pub overlap: bool,
}

impl ParCfg {
    pub fn single() -> ParCfg {
        ParCfg {
            topo: Topology::single(),
            sp: false,
            n_micro: 1,
            schedule: Schedule::GPipe,
            recompute: false,
            fp8: false,
            moe: false,
            zero1: false,
            overlap: false,
        }
    }

    pub fn validate(&self, m: &ModelCfg, layers: usize) -> Result<()> {
        let t = &self.topo;
        if m.h % t.tp != 0 || m.v % t.tp != 0 || m.f % t.tp != 0 {
            bail!("tp={} must divide heads/vocab/ffn of {}", t.tp, m.name);
        }
        if t.cp > 1 && m.s % (2 * t.cp) != 0 {
            bail!("cp={} needs seq divisible by 2*cp", t.cp);
        }
        if self.sp && (m.s / t.cp) % t.tp != 0 {
            bail!("sp needs local seq divisible by tp");
        }
        if layers % (t.pp * t.vpp) != 0 {
            bail!("layers={layers} must divide into pp*vpp={}", t.pp * t.vpp);
        }
        if self.fp8 && t.cp > 1 {
            bail!("fp8 artifacts are not generated for cp>1");
        }
        if self.moe && (t.cp > 1 || self.fp8) {
            bail!("moe artifacts are not generated for cp>1 or fp8");
        }
        Ok(())
    }
}

/// Derived local shapes for one rank under (ModelCfg, ParCfg) — the single
/// source of truth for both artifact keys and host-side tensor plumbing.
#[derive(Clone, Copy, Debug)]
pub struct Shapes {
    pub b: usize,
    pub s: usize,
    /// local sequence inside the attention block (S / cp)
    pub t_cp: usize,
    /// sequence at LN/residual points (t_cp / tp under SP)
    pub t_sp: usize,
    pub d: usize,
    pub hd: usize,
    /// heads per rank
    pub hp: usize,
    /// 3*D/tp — fused qkv output width per rank
    pub dp3: usize,
    /// D/tp — attention value width per rank
    pub dp: usize,
    /// ffn per rank
    pub fp: usize,
    /// vocab per rank
    pub vp: usize,
    pub e: usize,
}

impl Shapes {
    pub fn derive(m: &ModelCfg, p: &ParCfg) -> Shapes {
        let tp = p.topo.tp;
        let cp = p.topo.cp;
        let t_cp = m.s / cp;
        let t_sp = if p.sp { t_cp / tp } else { t_cp };
        Shapes {
            b: m.b,
            s: m.s,
            t_cp,
            t_sp,
            d: m.d,
            hd: m.head_dim(),
            hp: m.h / tp,
            dp3: 3 * m.d / tp,
            dp: m.d / tp,
            fp: m.f / tp,
            vp: m.v / tp,
            e: m.e,
        }
    }

    // ---- artifact keys (must mirror aot.py::variant_requests) ------------

    pub fn k_embed_fwd(&self) -> String {
        module_key("embed_fwd", &[self.b, self.t_cp, self.vp, self.d])
    }
    pub fn k_embed_bwd(&self) -> String {
        module_key("embed_bwd", &[self.b, self.t_cp, self.vp, self.d])
    }
    pub fn k_ln_fwd(&self) -> String {
        module_key("ln_fwd", &[self.b, self.t_sp, self.d])
    }
    pub fn k_ln_bwd(&self) -> String {
        module_key("ln_bwd", &[self.b, self.t_sp, self.d])
    }
    pub fn k_qkv_fwd(&self) -> String {
        module_key("linear_fwd", &[self.b, self.t_cp, self.d, self.dp3])
    }
    pub fn k_qkv_bwd(&self) -> String {
        module_key("linear_bwd", &[self.b, self.t_cp, self.d, self.dp3])
    }
    pub fn k_qkv_fp8_fwd(&self) -> String {
        module_key("linear_fp8_fwd", &[self.b, self.t_cp, self.d, self.dp3])
    }
    pub fn k_qkv_fp8_bwd(&self) -> String {
        module_key("linear_fp8_bwd", &[self.b, self.t_cp, self.d, self.dp3])
    }
    pub fn k_attn_fwd(&self) -> String {
        module_key("attn_fwd", &[self.b, self.hp, self.t_cp, self.s, self.hd])
    }
    pub fn k_attn_bwd(&self) -> String {
        module_key("attn_bwd", &[self.b, self.hp, self.t_cp, self.s, self.hd])
    }
    pub fn k_proj_fwd(&self) -> String {
        module_key("linearnb_fwd", &[self.b, self.t_cp, self.dp, self.d])
    }
    pub fn k_proj_bwd(&self) -> String {
        module_key("linearnb_bwd", &[self.b, self.t_cp, self.dp, self.d])
    }
    pub fn k_proj_fp8_fwd(&self) -> String {
        module_key("linearnb_fp8_fwd", &[self.b, self.t_cp, self.dp, self.d])
    }
    pub fn k_proj_fp8_bwd(&self) -> String {
        module_key("linearnb_fp8_bwd", &[self.b, self.t_cp, self.dp, self.d])
    }
    pub fn k_mlp_fwd(&self) -> String {
        module_key("mlp_fwd", &[self.b, self.t_cp, self.d, self.fp])
    }
    pub fn k_mlp_bwd(&self) -> String {
        module_key("mlp_bwd", &[self.b, self.t_cp, self.d, self.fp])
    }
    pub fn k_mlp_fp8_fwd(&self) -> String {
        module_key("mlp_fp8_fwd", &[self.b, self.t_cp, self.d, self.fp])
    }
    pub fn k_mlp_fp8_bwd(&self) -> String {
        module_key("mlp_fp8_bwd", &[self.b, self.t_cp, self.d, self.fp])
    }
    pub fn k_lmhead_fwd(&self) -> String {
        module_key("lmhead_fwd", &[self.b, self.t_cp, self.d, self.vp])
    }
    pub fn k_lmhead_bwd(&self) -> String {
        module_key("lmhead_bwd", &[self.b, self.t_cp, self.d, self.vp])
    }
    pub fn k_logits_max(&self) -> String {
        module_key("logits_max", &[self.b, self.t_cp, self.vp])
    }
    pub fn k_xent_local(&self) -> String {
        module_key("xent_local", &[self.b, self.t_cp, self.vp])
    }
    pub fn k_router_fwd(&self) -> String {
        module_key("router_fwd", &[self.b, self.t_sp, self.d, self.e])
    }
    pub fn k_router_bwd(&self) -> String {
        module_key("router_bwd", &[self.b, self.t_sp, self.d, self.e])
    }
    pub fn k_experts_fwd(&self) -> String {
        module_key("experts_fwd", &[self.b, self.t_cp, self.d, self.fp, self.e])
    }
    pub fn k_experts_bwd(&self) -> String {
        module_key("experts_bwd", &[self.b, self.t_cp, self.d, self.fp, self.e])
    }
}

/// All module keys of a (ModelCfg, ParCfg), formatted once at engine
/// construction. `Shapes::k_*` builds each key with `format!` — fine at
/// setup, too hot for the per-module execution path, where the engine runs
/// thousands of modules per iteration.
#[derive(Clone, Debug)]
pub struct ModKeys {
    pub embed_fwd: String,
    pub embed_bwd: String,
    pub ln_fwd: String,
    pub ln_bwd: String,
    pub qkv_fwd: String,
    pub qkv_bwd: String,
    pub qkv_fp8_fwd: String,
    pub qkv_fp8_bwd: String,
    pub attn_fwd: String,
    pub attn_bwd: String,
    pub proj_fwd: String,
    pub proj_bwd: String,
    pub proj_fp8_fwd: String,
    pub proj_fp8_bwd: String,
    pub mlp_fwd: String,
    pub mlp_bwd: String,
    pub mlp_fp8_fwd: String,
    pub mlp_fp8_bwd: String,
    pub lmhead_fwd: String,
    pub lmhead_bwd: String,
    pub logits_max: String,
    pub xent_local: String,
    pub router_fwd: String,
    pub router_bwd: String,
    pub experts_fwd: String,
    pub experts_bwd: String,
}

impl ModKeys {
    pub fn new(sh: &Shapes) -> ModKeys {
        ModKeys {
            embed_fwd: sh.k_embed_fwd(),
            embed_bwd: sh.k_embed_bwd(),
            ln_fwd: sh.k_ln_fwd(),
            ln_bwd: sh.k_ln_bwd(),
            qkv_fwd: sh.k_qkv_fwd(),
            qkv_bwd: sh.k_qkv_bwd(),
            qkv_fp8_fwd: sh.k_qkv_fp8_fwd(),
            qkv_fp8_bwd: sh.k_qkv_fp8_bwd(),
            attn_fwd: sh.k_attn_fwd(),
            attn_bwd: sh.k_attn_bwd(),
            proj_fwd: sh.k_proj_fwd(),
            proj_bwd: sh.k_proj_bwd(),
            proj_fp8_fwd: sh.k_proj_fp8_fwd(),
            proj_fp8_bwd: sh.k_proj_fp8_bwd(),
            mlp_fwd: sh.k_mlp_fwd(),
            mlp_bwd: sh.k_mlp_bwd(),
            mlp_fp8_fwd: sh.k_mlp_fp8_fwd(),
            mlp_fp8_bwd: sh.k_mlp_fp8_bwd(),
            lmhead_fwd: sh.k_lmhead_fwd(),
            lmhead_bwd: sh.k_lmhead_bwd(),
            logits_max: sh.k_logits_max(),
            xent_local: sh.k_xent_local(),
            router_fwd: sh.k_router_fwd(),
            router_bwd: sh.k_router_bwd(),
            experts_fwd: sh.k_experts_fwd(),
            experts_bwd: sh.k_experts_bwd(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_single_device() {
        let p = ParCfg::single();
        let s = Shapes::derive(&TINY, &p);
        assert_eq!(s.t_cp, 16);
        assert_eq!(s.t_sp, 16);
        assert_eq!(s.dp3, 96);
        assert_eq!(s.k_attn_fwd(), "attn_fwd__2_4_16_16_8");
    }

    #[test]
    fn shapes_tp2_sp_cp2() {
        let mut p = ParCfg::single();
        p.topo = Topology::new(1, 2, 1, 2, 1).unwrap();
        p.sp = true;
        let s = Shapes::derive(&TINY, &p);
        assert_eq!(s.t_cp, 8);
        assert_eq!(s.t_sp, 4);
        assert_eq!(s.hp, 2);
        assert_eq!(s.vp, 32);
        assert_eq!(s.k_ln_fwd(), "ln_fwd__2_4_32");
        assert_eq!(s.k_attn_fwd(), "attn_fwd__2_2_8_16_8");
    }

    #[test]
    fn validate_catches_bad_combos() {
        let m = TINY;
        let mut p = ParCfg::single();
        p.topo = Topology::new(1, 8, 1, 1, 1).unwrap();
        assert!(p.validate(&m, 2).is_err()); // tp=8 > heads=4
        let mut p2 = ParCfg::single();
        p2.topo = Topology::new(1, 1, 2, 1, 1).unwrap();
        assert!(p2.validate(&m, 3).is_err()); // 3 layers on 2 stages
        assert!(p2.validate(&m, 4).is_ok());
    }

    #[test]
    fn param_count_e2e_scale() {
        // e2e preset at 8 layers ≈ 7M params (documented in EXPERIMENTS.md)
        let n = E2E.param_count(8);
        assert!(n > 6_000_000 && n < 9_000_000, "{n}");
    }
}
