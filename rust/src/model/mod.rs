//! The distributed GPT training framework (the substrate TTrace checks):
//! configuration, parameters, sequence plumbing, the manual-backprop
//! engine, the GPipe/VPP pipeline driver and the mixed-precision
//! optimizer. Compute modules execute as AOT HLO via `runtime`.

mod backward;
pub mod config;
pub mod engine;
mod forward;
pub mod params;
pub mod seq;
pub mod step;
mod optimizer;

pub use config::{preset, ModelCfg, ParCfg, Schedule, Shapes, E2E, SMALL, TINY};
pub use engine::{Engine, RankState};
pub use step::{mean_losses, run_training, run_training_full,
               run_training_until, try_run_training, try_run_training_until};
