//! Sequence-domain plumbing: context-parallel striping, sequence-parallel
//! sub-slicing, causal masks, and small bf16 host ops the device modules
//! don't cover (bias adds, bias grads).
//!
//! CP striping (load-balanced causal attention): the sequence is cut into
//! `2*cp` chunks; rank `r` owns chunks `r` and `2cp-1-r`. Early chunks see
//! few keys, late chunks many — pairing them balances work. All stripe
//! arithmetic here must agree with `ShardSpec::and_cp_stripes`.

use crate::tensor::{DType, Tensor};
use crate::ttrace::shard::{Piece, ShardSpec};

/// The (global_start, len) stripe pieces rank `r` owns, in local order.
pub fn stripe_pieces(s: usize, cp: usize, r: usize) -> Vec<(usize, usize)> {
    if cp == 1 {
        return vec![(0, s)];
    }
    let chunk = s / (2 * cp);
    vec![(r * chunk, chunk), ((2 * cp - 1 - r) * chunk, chunk)]
}

/// Global position of every local sequence index on rank `r`.
pub fn seq_positions(s: usize, cp: usize, r: usize) -> Vec<usize> {
    stripe_pieces(s, cp, r)
        .into_iter()
        .flat_map(|(start, len)| start..start + len)
        .collect()
}

/// Sub-range [start, start+len) of a concatenated piece list (used to
/// compose SP slicing on top of CP striping).
pub fn pieces_subrange(pieces: &[(usize, usize)], start: usize, len: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut pos = 0usize; // local offset
    let end = start + len;
    for &(gs, plen) in pieces {
        let pstart = pos;
        let pend = pos + plen;
        let lo = start.max(pstart);
        let hi = end.min(pend);
        if lo < hi {
            out.push((gs + (lo - pstart), hi - lo));
        }
        pos = pend;
    }
    assert_eq!(out.iter().map(|p| p.1).sum::<usize>(), len,
               "subrange [{start},{end}) exceeds pieces");
    out
}

/// ShardSpec for a tensor whose `dim` is the sequence, sharded by CP
/// stripes and then (optionally) SP-sliced within the local stripes.
pub fn seq_spec(global_dims: &[usize], dim: usize, cp_rank: usize, cp: usize,
                sp_idx: usize, sp_n: usize) -> ShardSpec {
    let s = global_dims[dim];
    let stripes = stripe_pieces(s, cp, cp_rank);
    let local_len: usize = stripes.iter().map(|p| p.1).sum();
    let pieces = if sp_n > 1 {
        let t_sp = local_len / sp_n;
        pieces_subrange(&stripes, sp_idx * t_sp, t_sp)
    } else {
        stripes
    };
    let pieces = pieces
        .into_iter()
        .map(|(global_start, len)| Piece { global_start, len })
        .collect();
    ShardSpec::full(global_dims).and_pieces(dim, pieces)
}

/// Reassemble CP-striped parts (in cp-rank order, e.g. from an all-gather)
/// into global sequence order along `dim`.
pub fn cp_merge(parts: &[Tensor], dim: usize, cp: usize) -> Tensor {
    assert_eq!(parts.len(), cp);
    if cp == 1 {
        return parts[0].clone();
    }
    let local = parts[0].dims[dim];
    let chunk = local / 2;
    let mut ordered: Vec<Tensor> = Vec::with_capacity(2 * cp);
    for c in 0..2 * cp {
        let r = if c < cp { c } else { 2 * cp - 1 - c };
        let piece_idx = if c < cp { 0 } else { 1 };
        ordered.push(parts[r].narrow(dim, piece_idx * chunk, chunk));
    }
    let refs: Vec<&Tensor> = ordered.iter().collect();
    Tensor::concat(&refs, dim)
}

/// Extract rank `r`'s stripes from a global-order tensor along `dim`.
pub fn cp_extract(full: &Tensor, dim: usize, r: usize, cp: usize) -> Tensor {
    if cp == 1 {
        return full.clone();
    }
    let s = full.dims[dim];
    let chunk = s / (2 * cp);
    let a = full.narrow(dim, r * chunk, chunk);
    let b = full.narrow(dim, (2 * cp - 1 - r) * chunk, chunk);
    Tensor::concat(&[&a, &b], dim)
}

/// Additive-causal mask [len(q_positions), s_full] in f32: 0 where key
/// position <= query position, MASK_VALUE elsewhere.
pub const MASK_VALUE: f32 = -30000.0;

pub fn causal_mask(q_positions: &[usize], s_full: usize) -> Tensor {
    let rows = q_positions.len();
    let mut data = vec![0.0f32; rows * s_full];
    for (i, &qp) in q_positions.iter().enumerate() {
        for j in (qp + 1)..s_full {
            data[i * s_full + j] = MASK_VALUE;
        }
    }
    Tensor::new(&[rows, s_full], data, DType::F32)
}

/// Broadcast-add a bias over the last dimension, rounding through bf16
/// (what the device's bf16 add would produce).
pub fn add_bias_bf16(x: &Tensor, bias: &Tensor) -> Tensor {
    let d = *x.dims.last().unwrap();
    assert_eq!(bias.dims, vec![d]);
    let mut out = x.clone();
    for (i, v) in out.data.iter_mut().enumerate() {
        *v = crate::util::bf16::round_bf16(*v + bias.data[i % d]);
    }
    out.dtype = DType::Bf16;
    out
}

/// Gradient of a broadcast bias: sum over all leading dims (f32 accumulate,
/// bf16 result like the device wgrad kernels).
pub fn bias_grad(dy: &Tensor) -> Tensor {
    let d = *dy.dims.last().unwrap();
    let mut out = vec![0.0f32; d];
    for (i, v) in dy.data.iter().enumerate() {
        out[i % d] += v;
    }
    crate::util::bf16::round_slice_bf16(&mut out);
    Tensor::new(&[d], out, DType::Bf16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    #[test]
    fn stripes_match_shardspec() {
        for cp in [1usize, 2, 4] {
            let s = 16 * cp;
            for r in 0..cp {
                let spec = ShardSpec::full(&[s]).and_cp_stripes(0, r, cp);
                let expect: Vec<(usize, usize)> = if cp == 1 {
                    vec![(0, s)]
                } else {
                    spec.maps[0].pieces.iter().map(|p| (p.global_start, p.len)).collect()
                };
                assert_eq!(stripe_pieces(s, cp, r), expect, "cp={cp} r={r}");
            }
        }
    }

    #[test]
    fn merge_extract_roundtrip() {
        check("cp merge/extract roundtrip", |rng| {
            let cp = Gen::pow2(rng, 1, 4);
            let s = 2 * cp * Gen::pow2(rng, 1, 4);
            let full = Tensor::new(&[2, s], Gen::vec_normal(rng, 2 * s, 1.0),
                                   crate::tensor::DType::F32);
            let parts: Vec<Tensor> = (0..cp).map(|r| cp_extract(&full, 1, r, cp)).collect();
            if cp_merge(&parts, 1, cp) == full {
                Ok(())
            } else {
                Err(format!("cp={cp} s={s}"))
            }
        });
    }

    #[test]
    fn subrange_splits_pieces() {
        // pieces: [10..14), [30..34) — local len 8; take [2,6): crosses both
        let got = pieces_subrange(&[(10, 4), (30, 4)], 2, 4);
        assert_eq!(got, vec![(12, 2), (30, 2)]);
    }

    #[test]
    fn seq_spec_composes_sp_and_cp() {
        // S=16, cp=2 rank0 -> stripes (0..4),(12..16); sp 2-way idx 1 ->
        // local [4..8) = (12..16)
        let spec = seq_spec(&[2, 16, 8], 1, 0, 2, 1, 2);
        assert_eq!(spec.local_dims(), vec![2, 4, 8]);
        assert_eq!(spec.maps[0].pieces,
                   vec![Piece { global_start: 12, len: 4 }]);
    }

    #[test]
    fn causal_mask_semantics() {
        let m = causal_mask(&[0, 3], 4);
        // row 0: only key 0 visible; row 1 (pos 3): all visible
        assert_eq!(m.data[0], 0.0);
        assert_eq!(m.data[1], MASK_VALUE);
        assert_eq!(&m.data[4..8], &[0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn bias_ops() {
        let x = Tensor::new(&[2, 2], vec![1., 2., 3., 4.], DType::Bf16);
        let b = Tensor::new(&[2], vec![0.5, -0.5], DType::Bf16);
        let y = add_bias_bf16(&x, &b);
        assert_eq!(y.data, vec![1.5, 1.5, 3.5, 3.5]);
        let g = bias_grad(&x);
        assert_eq!(g.data, vec![4.0, 6.0]);
    }
}
