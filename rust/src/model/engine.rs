//! The distributed GPT training engine — the substrate TTrace checks.
//!
//! One `Engine` describes a training run (model dims, parallel layout,
//! armed bug); `run` executes it SPMD over simulated ranks. The engine is
//! a *manual-backprop* pipeline: every module's forward/backward is an AOT
//! HLO execution (`runtime::Executor`), every collective happens between
//! module calls in Rust — exactly the layer where Megatron's silent bugs
//! live, and exactly the hook surface TTrace traces.
//!
//! The reference (single-device) run is the same code with world size 1:
//! reference/candidate differences can only come from parallelization
//! semantics (or an armed bug), never from divergent code paths.

use std::collections::HashMap;

use anyhow::Result;

use crate::bugs::{BugId, BugSet};
use crate::comm::{RedOp, RedPrec};
use crate::dist::{Group, RankCtx};
use crate::runtime::Executor;
use crate::tensor::{DType, Tensor};
use crate::ttrace::canonical::LayerMap;
use crate::ttrace::hooks::{CanonId, Hooks, Kind};
use crate::ttrace::shard::ShardSpec;

use super::config::{ModKeys, ModelCfg, ParCfg, Shapes};
use super::params::{build as build_params, ParamSet};
use super::seq;

const E4M3_MAX: f32 = 448.0;
const E5M2_MAX: f32 = 57344.0;

pub struct Engine<'a> {
    pub m: ModelCfg,
    pub p: ParCfg,
    pub layers: usize,
    pub sh: Shapes,
    /// module keys, formatted once — the per-module hot path never allocates
    pub keys: ModKeys,
    pub lr: f32,
    pub exec: &'a Executor,
    pub bugs: BugSet,
}

/// Per-rank mutable training state.
pub struct RankState {
    pub params: ParamSet,
    pub lmap: LayerMap,
    /// chunk index v -> global layer ids this stage computes for chunk v
    pub chunks: Vec<Vec<usize>>,
    pub holds_embedding: bool,
    pub holds_lmhead: bool,
    pub adam_t: u64,
    /// delayed fp8 scales for tensors not observable on the host (the
    /// post-gelu activation inside the fused fp8 MLP)
    pub fp8_sh: HashMap<String, f32>,
    /// mean loss of the last iteration (last-stage ranks only)
    pub last_loss: Option<f64>,
    /// global gradient norm of the last iteration
    pub last_grad_norm: Option<f64>,
}

// ---------------------------------------------------------------------------
// tapes (saved forward state for manual backprop)
// ---------------------------------------------------------------------------

pub(crate) struct LayerInner {
    pub(crate) qkv_in: Tensor,
    pub(crate) q: Tensor,
    pub(crate) k_full: Tensor,
    pub(crate) v_full: Tensor,
    pub(crate) mask: Tensor,
    pub(crate) attn_out: Tensor,
    pub(crate) resid1: Tensor,
    pub(crate) ln2_out: Tensor,
    pub(crate) mlp_in: Tensor,
    pub(crate) combine_full: Option<Tensor>,
    /// fp8 scales used in fwd (must be reused in bwd): qkv(sx,sw),
    /// proj(sx,sw), mlp(sx,sw1,sh,sw2)
    pub(crate) scales: Vec<f32>,
}

pub(crate) struct LayerTape {
    pub(crate) layer: usize,
    pub(crate) x: Tensor,
    /// layer output (kept for the bug-2 stale-recompute fault)
    pub(crate) out: Tensor,
    pub(crate) inner: Option<LayerInner>,
}

pub(crate) struct HeadTape {
    pub(crate) resid: Tensor,
    pub(crate) x_head: Tensor,
    pub(crate) targets: Tensor,
    pub(crate) gmax: Tensor,
    pub(crate) gsum: Tensor,
}

pub(crate) struct ChunkTape {
    pub(crate) tokens: Option<Tensor>,
    pub(crate) layers: Vec<LayerTape>,
    pub(crate) head: Option<HeadTape>,
}

impl<'a> Engine<'a> {
    pub fn new(m: ModelCfg, p: ParCfg, layers: usize, exec: &'a Executor,
               bugs: BugSet) -> Result<Engine<'a>> {
        p.validate(&m, layers)?;
        let sh = Shapes::derive(&m, &p);
        let keys = ModKeys::new(&sh);
        Ok(Engine { m, p, layers, sh, keys, lr: 1e-3, exec, bugs })
    }

    pub fn init_rank(&self, ctx: &RankCtx) -> RankState {
        let topo = self.p.topo;
        let lmap = LayerMap::new(self.layers, topo.pp, topo.vpp).unwrap();
        // Bug 10: the stage-division code assigns each stage the layer
        // block of the *next* stage (a rotation) — shapes stay legal, the
        // composed model silently applies layers in the wrong order.
        let pp_for_layers = if self.bugs.on(BugId::B10PpStageDivision) && topo.pp > 1 {
            (ctx.coord.pp + 1) % topo.pp
        } else {
            ctx.coord.pp
        };
        let chunks: Vec<Vec<usize>> = (0..topo.vpp)
            .map(|v| lmap.chunk_layers(pp_for_layers, v))
            .collect();
        let holds_embedding = ctx.is_first_stage();
        let holds_lmhead = ctx.is_last_stage();
        let all_layers: Vec<usize> = chunks.iter().flatten().copied().collect();
        let params = build_params(&self.m, &self.p, ctx.coord, self.layers,
                                  &all_layers, holds_embedding, holds_lmhead);
        RankState {
            params,
            lmap,
            chunks,
            holds_embedding,
            holds_lmhead,
            adam_t: 0,
            fp8_sh: HashMap::new(),
            last_loss: None,
            last_grad_norm: None,
        }
    }

    // -------------------------------------------------------------------
    // small helpers
    // -------------------------------------------------------------------

    pub(crate) fn run_mod(&self, key: &str, inputs: &[&Tensor]) -> Vec<Tensor> {
        self.exec
            .run(key, inputs)
            .unwrap_or_else(|e| panic!("module {key}: {e:#}"))
    }

    pub(crate) fn ar_bf16(&self, ctx: &RankCtx, g: &Group, t: &Tensor) -> Tensor {
        if g.size == 1 {
            return t.clone();
        }
        ctx.comm.all_reduce(&g.key, g.me, g.size, t, RedOp::Sum, RedPrec::Bf16)
    }

    pub(crate) fn ar_f32(&self, ctx: &RankCtx, g: &Group, t: &Tensor) -> Tensor {
        if g.size == 1 {
            return t.clone();
        }
        ctx.comm.all_reduce(&g.key, g.me, g.size, t, RedOp::Sum, RedPrec::F32)
    }

    pub(crate) fn ar_max(&self, ctx: &RankCtx, g: &Group, t: &Tensor) -> Tensor {
        if g.size == 1 {
            return t.clone();
        }
        ctx.comm.all_reduce(&g.key, g.me, g.size, t, RedOp::Max, RedPrec::F32)
    }

    /// SP all-gather along the sequence dim (tp member order = seq order).
    pub(crate) fn sp_gather(&self, ctx: &RankCtx, t: &Tensor) -> Tensor {
        if !self.p.sp || self.p.topo.tp == 1 {
            return t.clone();
        }
        let g = ctx.tp_group();
        let parts = ctx.comm.all_gather(&g.key, g.me, g.size, t);
        let refs: Vec<&Tensor> = parts.iter().collect();
        Tensor::concat(&refs, 1)
    }

    /// Inverse of `sp_gather` for gradients: reduce(sum) + scatter my slice.
    pub(crate) fn sp_scatter_grad(&self, ctx: &RankCtx, t: &Tensor, prec: RedPrec) -> Tensor {
        if !self.p.sp || self.p.topo.tp == 1 {
            return t.clone();
        }
        let g = ctx.tp_group();
        ctx.comm.reduce_scatter(&g.key, g.me, g.size, t, 1, RedOp::Sum, prec)
    }

    /// Row-parallel output reduction: all-reduce, or reduce-scatter under SP.
    pub(crate) fn rowpar_reduce(&self, ctx: &RankCtx, t: &Tensor) -> Tensor {
        let g = ctx.tp_group();
        if g.size == 1 {
            return t.clone();
        }
        if self.p.sp {
            ctx.comm.reduce_scatter(&g.key, g.me, g.size, t, 1, RedOp::Sum,
                                    RedPrec::Bf16)
        } else {
            self.ar_bf16(ctx, &g, t)
        }
    }

    /// Backward of `rowpar_reduce`: identity (all-reduce) or all-gather (SP).
    pub(crate) fn rowpar_reduce_bwd(&self, ctx: &RankCtx, t: &Tensor) -> Tensor {
        self.sp_gather(ctx, t)
    }

    /// Column-parallel input-grad reduction (dx is a partial sum over tp).
    /// Bug 11: with comm/compute overlap armed, the all-reduce is skipped
    /// and the partial gradient flows on (M-CM).
    pub(crate) fn colpar_dx_reduce(&self, ctx: &RankCtx, t: &Tensor) -> Tensor {
        if self.bugs.on(BugId::B11TpOverlapGrads) && self.p.overlap {
            // the "overlapped" reduce never lands
            return if self.p.sp {
                // keep shapes legal under SP: local slice of the partial
                let g = ctx.tp_group();
                let len = t.dims[1] / g.size;
                t.narrow(1, g.me * len, len)
            } else {
                t.clone()
            };
        }
        if self.p.sp {
            self.sp_scatter_grad(ctx, t, RedPrec::Bf16)
        } else {
            let g = ctx.tp_group();
            self.ar_bf16(ctx, &g, t)
        }
    }

    /// Record an activation-kind tensor.
    pub(crate) fn rec(&self, hooks: &dyn Hooks, iter: u64, micro: u32, kind: Kind,
           module: &str, t: &Tensor, spec: ShardSpec) {
        hooks.record(&CanonId::new(iter, micro, kind, module), t, &spec);
    }

    /// ShardSpec for a residual-domain tensor [B, S, D] (sp+cp sharded).
    pub(crate) fn spec_sp(&self, ctx: &RankCtx) -> ShardSpec {
        let topo = self.p.topo;
        seq::seq_spec(&[self.sh.b, self.sh.s, self.sh.d], 1, ctx.coord.cp,
                      topo.cp, if self.p.sp { ctx.coord.tp } else { 0 },
                      if self.p.sp { topo.tp } else { 1 })
    }

    /// ShardSpec for an attention-domain tensor [B, S, width] (cp stripes,
    /// optional tp split of the last dim).
    pub(crate) fn spec_cp(&self, ctx: &RankCtx, width: usize, tp_split: bool) -> ShardSpec {
        let topo = self.p.topo;
        let mut spec = seq::seq_spec(&[self.sh.b, self.sh.s, width], 1,
                                     ctx.coord.cp, topo.cp, 0, 1);
        if tp_split && topo.tp > 1 {
            spec = spec.and_split(2, ctx.coord.tp, topo.tp);
        }
        spec
    }

    /// ShardSpec for the fused-QKV output [B, S, 3D].
    pub(crate) fn spec_qkv(&self, ctx: &RankCtx) -> ShardSpec {
        let topo = self.p.topo;
        let spec = seq::seq_spec(&[self.sh.b, self.sh.s, 3 * self.sh.d], 1,
                                 ctx.coord.cp, topo.cp, 0, 1);
        if topo.tp > 1 {
            spec.and_qkv_split(2, self.sh.d, ctx.coord.tp, topo.tp)
        } else {
            spec
        }
    }

    pub(crate) fn fp8_scale_e4m3(amax: f32) -> f32 {
        if amax <= 0.0 { 1.0 } else { E4M3_MAX / amax }
    }

    pub(crate) fn fp8_scale_e5m2(amax: f32) -> f32 {
        if amax <= 0.0 { 1.0 } else { E5M2_MAX / amax }
    }

    /// amax of a tensor synchronized over the fp8 scaling group (tp).
    /// Bug 7 syncs over the dp group instead — a wrong communication group
    /// that silently desynchronizes quantization grids vs the reference.
    pub(crate) fn fp8_amax(&self, ctx: &RankCtx, t: &Tensor) -> f32 {
        let local = Tensor::scalar(t.max_abs(), DType::F32);
        let g = if self.bugs.on(BugId::B7Fp8WrongGroup) {
            ctx.dp_group()
        } else {
            ctx.tp_group()
        };
        self.ar_max(ctx, &g, &local).data[0]
    }

    /// Split a fused-qkv activation [B,T,3Dp] into q,k,v in [B,Hp,T,hd].
    pub(crate) fn split_heads(&self, qkv: &Tensor) -> (Tensor, Tensor, Tensor) {
        let (b, t) = (qkv.dims[0], qkv.dims[1]);
        let dp = self.sh.dp;
        let to_heads = |x: Tensor| -> Tensor {
            x.reshape(&[b, t, self.sh.hp, self.sh.hd]).permute(&[0, 2, 1, 3])
        };
        let q = to_heads(qkv.narrow(2, 0, dp));
        let k = to_heads(qkv.narrow(2, dp, dp));
        let v = to_heads(qkv.narrow(2, 2 * dp, dp));
        (q, k, v)
    }

    /// Inverse of `split_heads`.
    pub(crate) fn merge_heads3(&self, dq: &Tensor, dk: &Tensor, dv: &Tensor) -> Tensor {
        let from_heads = |x: &Tensor| -> Tensor {
            let p = x.permute(&[0, 2, 1, 3]);
            let (b, t) = (p.dims[0], p.dims[1]);
            p.reshape(&[b, t, self.sh.dp])
        };
        let (q, k, v) = (from_heads(dq), from_heads(dk), from_heads(dv));
        Tensor::concat(&[&q, &k, &v], 2)
    }

    /// All-gather K/V over the cp group and reassemble global seq order.
    pub(crate) fn cp_gather_kv(&self, ctx: &RankCtx, t: &Tensor) -> Tensor {
        let cp = self.p.topo.cp;
        if cp == 1 {
            return t.clone();
        }
        let g = ctx.cp_group();
        let parts = ctx.comm.all_gather(&g.key, g.me, g.size, t);
        seq::cp_merge(&parts, 2, cp)
    }

    /// Backward of `cp_gather_kv`: sum every rank's full-seq contribution,
    /// then take my stripes. Bug 13 skips the sum (W-CP: each rank keeps
    /// only its own partial dK/dV).
    pub(crate) fn cp_scatter_kv_grad(&self, ctx: &RankCtx, t: &Tensor) -> Tensor {
        let cp = self.p.topo.cp;
        if cp == 1 {
            return t.clone();
        }
        let summed = if self.bugs.on(BugId::B13CpAttnGrads) {
            t.clone()
        } else {
            let g = ctx.cp_group();
            self.ar_bf16(ctx, &g, t)
        };
        seq::cp_extract(&summed, 2, ctx.coord.cp, cp)
    }
}

// The forward/backward bodies and the per-iteration driver live in
// `model::forward`, `model::backward`, `model::step` (separate impl blocks
// on `Engine` to keep files navigable).
