//! The per-iteration training driver: GPipe-style pipeline scheduling over
//! (virtual) chunks and microbatches, gradient finalization (the collective
//! choreography most of Table 1's bugs live in), and the entry point that
//! runs a full training job SPMD.

use std::collections::HashMap;

use crate::bugs::BugId;
use crate::data::DataSource;
use crate::dist::{run_spmd, try_run_spmd_opts, RankCtx, RankFailure,
                  SpmdOpts};
use crate::tensor::Tensor;
use crate::ttrace::hooks::{CanonId, Hooks, Kind};

use super::engine::{ChunkTape, Engine, RankState};
use super::params::GradSync;
use super::seq;

impl<'a> Engine<'a> {
    /// One training iteration. Returns the cp-averaged mean loss on
    /// last-stage ranks (None elsewhere).
    pub fn train_iter(&self, ctx: &RankCtx, st: &mut RankState,
                      hooks: &dyn Hooks, data: &dyn DataSource, iter: u64)
                      -> Option<f64> {
        for name in st.params.order.clone() {
            st.params.get_mut(&name).zero_grad();
        }
        let topo = self.p.topo;
        let pp = topo.pp;
        let last_chunk = topo.vpp * pp - 1;

        // ---- forward flush (GPipe; 1F1B is semantically identical in the
        // simulator since p2p sends are buffered) ----
        let mut tapes: Vec<Vec<ChunkTape>> = Vec::with_capacity(topo.vpp);
        let mut edges: HashMap<(usize, u32), Tensor> = HashMap::new();
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        for v in 0..topo.vpp {
            let chunk_layers = st.chunks[v].clone();
            let mut mtapes = Vec::with_capacity(self.p.n_micro);
            for m in 0..self.p.n_micro {
                let gmicro = (m * topo.dp + ctx.coord.dp) as u32;
                let g = v * pp + ctx.coord.pp;
                let mut tokens_saved = None;
                let x_in: Tensor = if g == 0 {
                    let batch = data.batch(iter, gmicro, self.sh.b, self.sh.s,
                                           self.m.v);
                    let tokens_full = batch.narrow(1, 0, self.sh.s);
                    let tokens = seq::cp_extract(&tokens_full, 1,
                                                 ctx.coord.cp, topo.cp);
                    let x = self.embed_fwd_path(ctx, st, hooks, iter, gmicro,
                                                &tokens);
                    tokens_saved = Some(tokens);
                    x
                } else {
                    let prev_pp = (g - 1) % pp;
                    if prev_pp == ctx.coord.pp {
                        edges.remove(&(g - 1, gmicro)).expect("local fwd edge")
                    } else {
                        ctx.comm.recv(ctx.pp_rank(prev_pp), ctx.rank, "act")
                    }
                };
                let (out, ltapes) = self.chunk_fwd(ctx, st, hooks, iter,
                                                   gmicro, &chunk_layers, x_in);
                let mut head = None;
                if g == last_chunk {
                    let batch = data.batch(iter, gmicro, self.sh.b, self.sh.s,
                                           self.m.v);
                    let targets_full = batch.narrow(1, 1, self.sh.s);
                    let targets = seq::cp_extract(&targets_full, 1,
                                                  ctx.coord.cp, topo.cp);
                    let (loss, htape) = self.head_fwd(ctx, st, hooks, iter,
                                                      gmicro, out, &targets);
                    loss_sum += loss;
                    loss_n += 1;
                    head = Some(htape);
                } else {
                    let next_pp = (g + 1) % pp;
                    if next_pp == ctx.coord.pp {
                        edges.insert((g, gmicro), out);
                    } else {
                        ctx.comm.send(ctx.rank, ctx.pp_rank(next_pp), "act", &out);
                    }
                }
                mtapes.push(ChunkTape { tokens: tokens_saved, layers: ltapes, head });
            }
            tapes.push(mtapes);
        }

        // ---- backward flush ----
        let mut gedges: HashMap<(usize, u32), Tensor> = HashMap::new();
        for v in (0..topo.vpp).rev() {
            for m in (0..self.p.n_micro).rev() {
                let gmicro = (m * topo.dp + ctx.coord.dp) as u32;
                let g = v * pp + ctx.coord.pp;
                let tape = &tapes[v][m];
                let mut d: Tensor = if g == last_chunk {
                    self.head_bwd(ctx, st, hooks, iter, gmicro,
                                  tape.head.as_ref().unwrap())
                } else {
                    let next_pp = (g + 1) % pp;
                    if next_pp == ctx.coord.pp {
                        gedges.remove(&(g, gmicro)).expect("local bwd edge")
                    } else {
                        ctx.comm.recv(ctx.pp_rank(next_pp), ctx.rank, "grad")
                    }
                };
                for lt in tape.layers.iter().rev() {
                    d = self.layer_bwd(ctx, st, hooks, iter, gmicro, lt, &d);
                }
                if g == 0 {
                    self.embed_bwd_path(ctx, st, hooks, iter, gmicro,
                                        tape.tokens.as_ref().unwrap(), &d);
                } else {
                    let prev_pp = (g - 1) % pp;
                    if prev_pp == ctx.coord.pp {
                        gedges.insert((g - 1, gmicro), d);
                    } else {
                        ctx.comm.send(ctx.rank, ctx.pp_rank(prev_pp), "grad", &d);
                    }
                }
            }
        }
        drop(tapes);

        self.finalize_grads(ctx, st, hooks, iter);
        st.last_grad_norm = Some(self.global_grad_norm(ctx, st));
        self.optimizer_step(ctx, st, hooks, iter);

        if loss_n > 0 {
            let l = loss_sum / loss_n as f64;
            st.last_loss = Some(l);
            Some(l)
        } else {
            None
        }
    }

    /// Gradient finalization: the collective choreography of main grads.
    /// Bugs 5, 6, 12, 14 are injected here.
    pub(crate) fn finalize_grads(&self, ctx: &RankCtx, st: &mut RankState,
                                 hooks: &dyn Hooks, iter: u64) {
        let topo = self.p.topo;
        let tpg = ctx.tp_group();

        // 1. replicated-but-sequence-sharded params need a tp all-reduce
        if tpg.size > 1 {
            for name in st.params.order.clone() {
                let p = st.params.get(&name);
                if p.sync != GradSync::ReplicatedSeqSharded {
                    continue;
                }
                let is_ln = name.contains("layernorm") || name.contains("linear_proj.bias");
                let is_router = name.contains("router");
                // Bug 12 (M-CM): the SP layernorm grad sync is missing.
                if self.bugs.on(BugId::B12SpLnSync) && is_ln {
                    continue;
                }
                // Bug 6 (M-CM): the router grad sync is missing.
                if self.bugs.on(BugId::B6SpRouterSync) && is_router {
                    continue;
                }
                let grad = p.main_grad.clone();
                let mut red = self.ar_f32(ctx, &tpg, &grad);
                // Bug 14 (W-CP): under TP+CP the layernorm grad reduction
                // averages instead of summing — wrong by a factor of tp.
                if self.bugs.on(BugId::B14TpCpLnGrads) && is_ln && topo.cp > 1 {
                    red = red.scale(1.0 / tpg.size as f32);
                }
                st.params.get_mut(&name).main_grad = red;
            }
        }

        // 2. tied-embedding grad sync between the first and last stages.
        // Bug 5 (W-CM): skipped when the distributed optimizer is on.
        if topo.pp > 1 && (st.holds_embedding || st.holds_lmhead) {
            let skip = self.bugs.on(BugId::B5ZeroUntiedEmbedding) && self.p.zero1;
            if !skip {
                let c = ctx.coord;
                let key = format!("embtie@dp{}tp{}cp{}", c.dp, c.tp, c.cp);
                let me = if st.holds_embedding { 0 } else { 1 };
                let grad = st.params.get("embedding.word_embeddings.weight")
                    .main_grad.clone();
                let red = ctx.comm.all_reduce(&key, me, 2, &grad,
                                              crate::comm::RedOp::Sum,
                                              crate::comm::RedPrec::F32);
                st.params.get_mut("embedding.word_embeddings.weight").main_grad = red;
            }
        }

        // 3. dp×cp main-grad all-reduce (f32)
        let dpcp = ctx.dpcp_group();
        if dpcp.size > 1 {
            for name in st.params.order.clone() {
                let grad = st.params.get(&name).main_grad.clone();
                let red = self.ar_f32(ctx, &dpcp, &grad);
                st.params.get_mut(&name).main_grad = red;
            }
        }

        // 4. record the final main grads
        for name in st.params.order.clone() {
            let p = st.params.get(&name);
            hooks.record(&CanonId::new(iter, 0, Kind::MainGrad, &name),
                         &p.main_grad, &p.spec);
        }
    }

    /// Global L2 norm of the main gradients across all *unique* parameter
    /// shards (replicated params counted on tp rank 0 / the first stage
    /// only) — the quantity plotted in the paper's Figure 1 next to the
    /// loss curve.
    pub(crate) fn global_grad_norm(&self, ctx: &RankCtx, st: &RankState) -> f64 {
        let mut local = 0.0f64;
        for name in &st.params.order {
            let p = st.params.get(name);
            let counted = match p.sync {
                super::params::GradSync::Sharded => {
                    // tied embedding lives on first AND last stage
                    name != "embedding.word_embeddings.weight" || st.holds_embedding
                }
                _ => ctx.coord.tp == 0,
            };
            // dp/cp replicas hold identical post-reduce grads: count dp0/cp0
            if counted && ctx.coord.dp == 0 && ctx.coord.cp == 0 {
                local += p.main_grad.fro_norm().powi(2);
            }
        }
        let g = ctx.world_group();
        let t = crate::tensor::Tensor::scalar(local as f32, crate::tensor::DType::F32);
        let sum = ctx.comm.all_reduce(&g.key, g.me, g.size, &t,
                                      crate::comm::RedOp::Sum,
                                      crate::comm::RedPrec::F32);
        (sum.data[0] as f64).sqrt()
    }
}

/// Run `iters` training iterations SPMD; returns each rank's per-iteration
/// losses (empty for non-last-stage ranks).
pub fn run_training(engine: &Engine, data: &dyn DataSource, hooks: &dyn Hooks,
                    iters: u64) -> Vec<Vec<f64>> {
    run_training_full(engine, data, hooks, iters)
        .into_iter()
        .map(|(l, _)| l)
        .collect()
}

/// Like `run_training` but also returns each rank's per-iteration global
/// gradient norms (identical on every rank).
pub fn run_training_full(engine: &Engine, data: &dyn DataSource,
                         hooks: &dyn Hooks, iters: u64)
                         -> Vec<(Vec<f64>, Vec<f64>)> {
    run_spmd(engine.p.topo, |ctx| {
        let mut st = engine.init_rank(ctx);
        let mut losses = Vec::new();
        let mut norms = Vec::new();
        for it in 0..iters {
            if let Some(l) = engine.train_iter(ctx, &mut st, hooks, data, it) {
                losses.push(l);
            }
            if let Some(n) = st.last_grad_norm {
                norms.push(n);
            }
        }
        (losses, norms)
    })
}

/// Stop-aware twin of [`run_training`] for live sessions: before every
/// iteration the ranks *agree* on whether a stop was requested (a world
/// all-reduce of the flag bit), so either every rank enters the iteration
/// or none does — an asynchronously raised flag can never leave a
/// collective half-entered. The flag is typically
/// [`Session::stop_flag`](crate::ttrace::api::Session::stop_flag), raised
/// by the streaming checker's `Control::Stop` verdict.
pub fn run_training_until(engine: &Engine, data: &dyn DataSource,
                          hooks: &dyn Hooks, iters: u64,
                          stop: &std::sync::atomic::AtomicBool)
                          -> Vec<Vec<f64>> {
    run_spmd(engine.p.topo, |ctx| {
        let mut st = engine.init_rank(ctx);
        let mut losses = Vec::new();
        for it in 0..iters {
            if stop_agreed(ctx, stop) {
                break;
            }
            if let Some(l) = engine.train_iter(ctx, &mut st, hooks, data, it) {
                losses.push(l);
            }
        }
        losses
    })
}

/// Stop-aware twin of [`try_run_training`] (live session + fault plan).
pub fn try_run_training_until(engine: &Engine, data: &dyn DataSource,
                              hooks: &dyn Hooks, iters: u64, opts: SpmdOpts,
                              stop: &std::sync::atomic::AtomicBool)
                              -> Vec<Result<Vec<f64>, RankFailure>> {
    try_run_spmd_opts(engine.p.topo, opts, |ctx| {
        let mut st = engine.init_rank(ctx);
        let mut losses = Vec::new();
        for it in 0..iters {
            if stop_agreed(ctx, stop) {
                break;
            }
            if let Some(l) = engine.train_iter(ctx, &mut st, hooks, data, it) {
                losses.push(l);
            }
        }
        losses
    })
}

/// World-agreement on the stop bit: any rank seeing the flag raised makes
/// *all* ranks break at the same iteration boundary.
fn stop_agreed(ctx: &RankCtx, stop: &std::sync::atomic::AtomicBool) -> bool {
    let raised = stop.load(std::sync::atomic::Ordering::SeqCst);
    let g = ctx.world_group();
    if g.size == 1 {
        return raised;
    }
    let t = Tensor::scalar(if raised { 1.0 } else { 0.0 },
                           crate::tensor::DType::F32);
    let sum = ctx.comm.all_reduce(&g.key, g.me, g.size, &t,
                                  crate::comm::RedOp::Sum,
                                  crate::comm::RedPrec::F32);
    sum.data[0] > 0.0
}

/// Fault-tolerant twin of [`run_training`]: runs under
/// [`crate::dist::try_run_spmd_opts`], so an injected (or organic) rank
/// crash, stall or straggler never deadlocks the harness — each rank comes
/// back as `Ok(losses)` or a structured [`RankFailure`] (hang report,
/// peer-crash, or panic detail). The `opts` carry the rendezvous deadline
/// and the armed fault plan.
pub fn try_run_training(engine: &Engine, data: &dyn DataSource,
                        hooks: &dyn Hooks, iters: u64, opts: SpmdOpts)
                        -> Vec<Result<Vec<f64>, RankFailure>> {
    try_run_spmd_opts(engine.p.topo, opts, |ctx| {
        let mut st = engine.init_rank(ctx);
        let mut losses = Vec::new();
        for it in 0..iters {
            if let Some(l) = engine.train_iter(ctx, &mut st, hooks, data, it) {
                losses.push(l);
            }
        }
        losses
    })
}

/// Convenience: mean loss per iteration across all loss-reporting ranks.
pub fn mean_losses(per_rank: &[Vec<f64>]) -> Vec<f64> {
    let reporting: Vec<&Vec<f64>> = per_rank.iter().filter(|l| !l.is_empty()).collect();
    if reporting.is_empty() {
        return Vec::new();
    }
    let iters = reporting[0].len();
    (0..iters)
        .map(|i| reporting.iter().map(|l| l[i]).sum::<f64>() / reporting.len() as f64)
        .collect()
}
