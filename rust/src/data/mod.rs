//! Training data substrate.
//!
//! `GenData` draws deterministic synthetic batches from the consistent
//! generator: the reference and every candidate rank reconstruct the same
//! logical batch for a given (iteration, global microbatch), which is what
//! makes differential testing possible (paper §4.2).
//!
//! `CorpusData` is a tiny character-level corpus pipeline for the
//! end-to-end training example: deterministic tokenization, contiguous
//! window sampling, same interface.

use crate::tensor::{DType, Tensor};
use crate::ttrace::gen;

pub trait DataSource: Sync {
    /// Token batch [b, s+1] (I32) for global microbatch `gmicro` of `iter`.
    /// Column 0..s are inputs, 1..s+1 the shifted targets.
    fn batch(&self, iter: u64, gmicro: u32, b: usize, s: usize, vocab: usize) -> Tensor;
}

/// Synthetic stream: uniform token ids from the named generator.
pub struct GenData;

impl DataSource for GenData {
    fn batch(&self, iter: u64, gmicro: u32, b: usize, s: usize, vocab: usize) -> Tensor {
        gen::full_ints(&format!("data/i{iter}/m{gmicro}"), &[b, s + 1], vocab as u64)
    }
}

/// Character-level corpus: repeats a training text, hashing windows
/// deterministically per (iter, gmicro, row).
pub struct CorpusData {
    tokens: Vec<i32>,
    vocab: usize,
}

impl CorpusData {
    /// Build from raw text with a byte-level vocabulary capped at `vocab`
    /// (bytes >= vocab wrap around — keeps any text usable with any model).
    pub fn from_text(text: &str, vocab: usize) -> CorpusData {
        let tokens: Vec<i32> = text.bytes().map(|b| (b as usize % vocab) as i32).collect();
        assert!(tokens.len() >= 2, "corpus too small");
        CorpusData { tokens, vocab }
    }

    /// A built-in tiny-shakespeare-flavoured corpus so the e2e example has
    /// real (non-uniform) token statistics without external files.
    pub fn builtin(vocab: usize) -> CorpusData {
        let text = include_str!("tiny_corpus.txt");
        CorpusData::from_text(text, vocab)
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

impl DataSource for CorpusData {
    fn batch(&self, iter: u64, gmicro: u32, b: usize, s: usize, vocab: usize) -> Tensor {
        assert_eq!(vocab, self.vocab, "corpus vocab mismatch");
        let n = self.tokens.len();
        let mut data = Vec::with_capacity(b * (s + 1));
        for row in 0..b {
            let seed = format!("corpus/i{iter}/m{gmicro}/r{row}");
            let start = (crate::util::rng::fnv1a(seed.as_bytes()) as usize)
                % n.saturating_sub(s + 1).max(1);
            for k in 0..s + 1 {
                data.push(self.tokens[(start + k) % n] as f32);
            }
        }
        Tensor::new(&[b, s + 1], data, DType::I32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gendata_is_deterministic_and_in_range() {
        let d = GenData;
        let a = d.batch(3, 1, 2, 8, 64);
        let b = d.batch(3, 1, 2, 8, 64);
        assert_eq!(a, b);
        assert_ne!(a, d.batch(3, 2, 2, 8, 64));
        for &v in &a.data {
            assert!((0.0..64.0).contains(&v));
        }
    }

    #[test]
    fn corpus_batches() {
        let c = CorpusData::from_text("hello world, this is a tiny corpus for testing!", 64);
        let t = c.batch(0, 0, 2, 8, 64);
        assert_eq!(t.dims, vec![2, 9]);
        for &v in &t.data {
            assert!((0.0..64.0).contains(&v));
        }
        assert_eq!(t, c.batch(0, 0, 2, 8, 64));
    }

    #[test]
    fn builtin_corpus_loads() {
        let c = CorpusData::builtin(2048);
        assert!(c.len() > 1000);
    }
}
