"""L1 Pallas kernel: tiled online-softmax (flash) attention forward.

This is the compute hot-spot of the transformer candidate/reference models
that TTrace checks. The paper's substrate runs CUDA FlashAttention; per the
hardware-adaptation rule we re-think it for TPU idioms instead of porting
warp-level code:

  - the grid iterates (batch, head, q-tile); each q-tile is resident in
    VMEM (the TPU scratchpad) for the whole pass,
  - K/V are streamed tile-by-tile from HBM via ``pl.ds`` loads — the
    BlockSpec/ds schedule plays the role the paper's threadblock loop
    plays on GPUs,
  - score/accumulator math is f32 (MXU-accumulate analogue); the P·V
    product is fed through bf16 operands like an MXU matmul would be.

Run under ``interpret=True`` on CPU: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. VMEM footprint / MXU
utilization for the TPU-shaped tile sizes are estimated in DESIGN.md §Perf.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BF16 = jnp.bfloat16
F32 = jnp.float32

NEG_INF = -1e30


def _attn_fwd_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, block_k: int,
                     skv: int, scale: float):
    """One (batch, head, q-tile) program instance.

    q_ref: [1, 1, bq, hd] VMEM-resident query tile
    k_ref, v_ref: [1, 1, Skv, hd] full key/value for this (b, h)
    m_ref: [bq, Skv] additive mask tile (f32)
    o_ref: [1, 1, bq, hd] output tile
    """
    q = q_ref[0, 0].astype(F32) * scale  # [bq, hd]
    bq = q.shape[0]
    hd = q.shape[1]

    def body(i, carry):
        m_i, l_i, acc = carry
        # Leading (b, h) dims are indexed with size-1 dynamic slices (not
        # bare ints): interpret-mode discharge rejects scalar indices mixed
        # with pl.ds on this jaxlib.
        kblk = pl.load(k_ref, (pl.ds(0, 1), pl.ds(0, 1),
                               pl.ds(i * block_k, block_k),
                               slice(None)))[0, 0].astype(F32)  # [bk, hd]
        vblk = pl.load(v_ref, (pl.ds(0, 1), pl.ds(0, 1),
                               pl.ds(i * block_k, block_k),
                               slice(None)))[0, 0]  # [bk, hd] bf16
        mblk = pl.load(m_ref, (slice(None), pl.ds(i * block_k, block_k)))
        s = q @ kblk.T + mblk  # [bq, bk] f32
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[:, None])  # [bq, bk] f32
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        # MXU-style product: bf16 operands, f32 accumulation.
        pv = jnp.matmul(p.astype(BF16), vblk, preferred_element_type=F32)
        acc_new = acc * alpha[:, None] + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, F32)
    l0 = jnp.zeros((bq,), F32)
    acc0 = jnp.zeros((bq, hd), F32)
    nsteps = skv // block_k
    m_i, l_i, acc = jax.lax.fori_loop(0, nsteps, body, (m0, l0, acc0))
    # Guard fully-masked rows (cannot happen for causal masks but keeps the
    # kernel total for arbitrary masks the coordinator may feed it).
    l_safe = jnp.where(l_i == 0.0, 1.0, l_i)
    o_ref[0, 0] = (acc / l_safe[:, None]).astype(BF16)


def attention_pallas(q, k, v, mask, *, block_q: int = 0, block_k: int = 0,
                     interpret: bool = True):
    """Flash-attention forward matching ``ref.attention_ref`` semantics.

    q: [B, H, Sq, hd] bf16;  k, v: [B, H, Skv, hd] bf16
    mask: [Sq, Skv] f32 additive
    """
    b, h, sq, hd = q.shape
    skv = k.shape[2]
    bq = block_q or _pick_block(sq)
    bk = block_k or _pick_block(skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)

    kernel = functools.partial(_attn_fwd_kernel, block_k=bk, skv=skv,
                               scale=1.0 / math.sqrt(hd))
    grid = (b, h, sq // bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda ib, ih, iq: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, skv, hd), lambda ib, ih, iq: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, skv, hd), lambda ib, ih, iq: (ib, ih, 0, 0)),
            pl.BlockSpec((bq, skv), lambda ib, ih, iq: (iq, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda ib, ih, iq: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), BF16),
        interpret=interpret,
    )(q, k, v, mask)


def _pick_block(n: int) -> int:
    """Largest power-of-two tile <= min(n, 128) that divides n — 128 matches
    the MXU systolic array on real TPU; on CPU-interpret it also minimizes
    while-loop trip counts, which dominated the attention profile
    (EXPERIMENTS.md §Perf iteration 1: 16.8ms -> measured below)."""
    for cand in (128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= n and n % cand == 0:
            return cand
    return 1


def attention_bwd_formula(q, k, v, mask, do):
    """Flash-style backward: recompute scores, use the softmax identity
    dS = P * (dP - rowsum(dP * P)). Matches ``attention_ref``'s vjp up to
    bf16 round-off; lowered into the attn_bwd HLO by the L2 model.
    """
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=F32)
    s = s * scale + mask.astype(F32)[None, None, :, :]
    p = jax.nn.softmax(s, axis=-1)  # [B,H,Sq,Skv] f32
    dof = do.astype(F32)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
    dp = jnp.einsum("bhqd,bhkd->bhqk", dof, v.astype(F32))
    delta = jnp.sum(dp * p, axis=-1, keepdims=True)
    ds = p * (dp - delta) * scale
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, k.astype(F32))
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q.astype(F32))
    return dq.astype(BF16), dk.astype(BF16), dv.astype(BF16)
