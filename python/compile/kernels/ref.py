"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the ground-truth semantics every kernel in this package must
match (pytest + hypothesis sweep them against the kernels). They are also
used by the L2 model as the *backward* path: the backward HLOs are lowered
as ``jax.vjp`` of these reference functions, recomputing the forward inside
the backward (activation-recomputation style), so no saved intermediates
cross the Rust/HLO boundary.

Precision model (BF16 mixed precision, matching Megatron-style recipes):
  - activations / parameters: bfloat16
  - matmul accumulation: float32 (``preferred_element_type``)
  - softmax / normalization statistics: float32
  - cross-entropy: float32
"""

import jax
import jax.numpy as jnp

BF16 = jnp.bfloat16
F32 = jnp.float32

# Large-but-finite additive mask value. -inf breaks bf16 arithmetic in some
# XLA CPU paths; -30000 underflows exp() identically for our value ranges.
MASK_VALUE = -30000.0


def matmul_f32(a, b):
    """bf16 x bf16 matmul with f32 accumulation, returns f32."""
    return jnp.matmul(a, b, preferred_element_type=F32)


def gelu(x):
    """tanh-approximated GeLU, computed in f32."""
    xf = x.astype(F32)
    c = 0.7978845608028654  # sqrt(2/pi)
    return 0.5 * xf * (1.0 + jnp.tanh(c * (xf + 0.044715 * xf**3)))


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last dim; stats in f32; output bf16."""
    xf = x.astype(F32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mean) ** 2, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    y = (xf - mean) * rstd * gamma.astype(F32) + beta.astype(F32)
    return y.astype(BF16)


def attention_ref(q, k, v, mask):
    """Scaled dot-product attention with an additive mask.

    q: [B, H, Sq, hd] bf16;  k, v: [B, H, Skv, hd] bf16
    mask: [Sq, Skv] bf16 additive (0 where visible, MASK_VALUE where not)
    returns: [B, H, Sq, hd] bf16
    """
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, F32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=F32)
    s = s * scale + mask.astype(F32)[None, None, :, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(BF16), v,
                   preferred_element_type=F32)
    return o.astype(BF16)


def linear_ref(x, w, b=None):
    """x @ w (+ b). x: [..., din] bf16, w: [din, dout] bf16."""
    y = matmul_f32(x, w)
    if b is not None:
        y = y + b.astype(F32)
    return y.astype(BF16)


def mlp_ref(x, w1, b1, w2):
    """fc1 -> gelu -> fc2 (no fc2 bias: row-parallel, bias added by the
    coordinator after the all-reduce)."""
    h = matmul_f32(x, w1) + b1.astype(F32)
    a = gelu(h.astype(BF16))
    y = matmul_f32(a.astype(BF16), w2)
    return y.astype(BF16)


def embed_ref(tokens, table, offset):
    """Vocab-sharded embedding lookup with the Megatron mask trick.

    tokens: [B, S] i32 (global vocab ids); table: [Vp, D] bf16 (this rank's
    shard); offset: scalar i32, first vocab id owned by this shard.
    Out-of-shard tokens contribute zeros; the coordinator all-reduces the
    partial outputs across the TP group. (Bug #1 corrupts ``offset``.)
    """
    vp = table.shape[0]
    idx = tokens.astype(jnp.int32) - offset
    in_shard = (idx >= 0) & (idx < vp)
    safe = jnp.clip(idx, 0, vp - 1)
    out = jnp.take(table, safe, axis=0)
    return jnp.where(in_shard[..., None], out, jnp.zeros_like(out))


def embed_grad_ref(tokens, dy, offset, vp):
    """Gradient of embed_ref w.r.t. the table shard: masked scatter-add."""
    idx = tokens.astype(jnp.int32) - offset
    in_shard = (idx >= 0) & (idx < vp)
    safe = jnp.clip(idx, 0, vp - 1)
    contrib = jnp.where(in_shard[..., None], dy.astype(F32),
                        jnp.zeros(dy.shape, F32))
    flat_idx = safe.reshape(-1)
    flat = contrib.reshape(-1, dy.shape[-1])
    dtable = jnp.zeros((vp, dy.shape[-1]), F32).at[flat_idx].add(flat)
    return dtable.astype(BF16)


def lmhead_logits_ref(x, table):
    """Vocab-parallel LM head: logits over this rank's vocab shard, f32.

    x: [B, S, D] bf16; table: [Vp, D] bf16 (tied embedding shard).
    """
    return matmul_f32(x, table.T)


def xent_local_ref(logits, targets, offset, gmax):
    """Local pieces of the vocab-parallel cross-entropy.

    Given logits [B,S,Vp] f32 for this vocab shard, the global max gmax
    [B,S] f32 (coordinator all-reduce-max of per-shard maxima), returns
      sumexp [B,S] f32  — sum of exp(logit - gmax) over the local shard
      tlogit [B,S] f32  — (target_logit - gmax) if the target id falls in
                          this shard, else 0 (all-reduce-sum reconstructs it)
    """
    vp = logits.shape[-1]
    sumexp = jnp.sum(jnp.exp(logits - gmax[..., None]), axis=-1)
    idx = targets.astype(jnp.int32) - offset
    in_shard = (idx >= 0) & (idx < vp)
    safe = jnp.clip(idx, 0, vp - 1)
    tl = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    tlogit = jnp.where(in_shard, tl - gmax, jnp.zeros_like(gmax))
    return sumexp, tlogit


def xent_dlogits_ref(logits, targets, offset, gmax, gsum, scale):
    """d(loss)/d(logits) for the local vocab shard.

    loss (per token) = log(gsum) - tlogit ; dlogits = (softmax - onehot)*scale
    scale: [B,S] f32 per-token loss scale (1/num_tokens etc. — the
    coordinator owns it; bugs #3/#4 corrupt it).
    """
    vp = logits.shape[-1]
    p = jnp.exp(logits - gmax[..., None]) / gsum[..., None]
    idx = targets.astype(jnp.int32) - offset
    in_shard = (idx >= 0) & (idx < vp)
    safe = jnp.clip(idx, 0, vp - 1)
    onehot = jax.nn.one_hot(safe, vp, dtype=F32) * in_shard[..., None]
    return (p - onehot) * scale[..., None]


# ---------------------------------------------------------------------------
# FP8 (e4m3) emulation — software quantize-dequantize with a per-tensor
# scale, mirroring TransformerEngine's delayed-scaling recipe. The scale is
# computed and synchronized by the Rust coordinator (bug #7 syncs it over
# the wrong group; bug #8 applies the wrong cast during recomputation).
# ---------------------------------------------------------------------------

E4M3_MAX = 448.0


def fp8_quant_dequant_ref(x, scale):
    """Quantize x (bf16) to float8_e4m3fn at x*scale, then dequantize (f32)."""
    xf = x.astype(F32) * scale
    xf = jnp.clip(xf, -E4M3_MAX, E4M3_MAX)
    q = xf.astype(jnp.float8_e4m3fn)
    return q.astype(F32) / scale


def linear_fp8_ref(x, w, scale_x, scale_w, b=None):
    """FP8-emulated linear: quantize inputs and weights to e4m3, matmul with
    f32 accumulation, bf16 output — the TPU analogue of FP8 tensor-core MMA
    with higher-precision accumulation."""
    xq = fp8_quant_dequant_ref(x, scale_x)
    wq = fp8_quant_dequant_ref(w, scale_w)
    y = jnp.matmul(xq, wq, preferred_element_type=F32)
    if b is not None:
        y = y + b.astype(F32)
    return y.astype(BF16)


def router_ref(x, wr):
    """Top-1 router for the dense-MoE layer: returns per-expert combine
    weights [B,S,E] f32 (gate prob on the argmax expert, 0 elsewhere)."""
    logits = matmul_f32(x, wr)
    g = jax.nn.softmax(logits, axis=-1)
    top = jnp.argmax(g, axis=-1)
    onehot = jax.nn.one_hot(top, g.shape[-1], dtype=F32)
    return g * onehot


def moe_ref(x, wr, w1, b1, w2):
    """Dense top-1 MoE: every expert runs on every token, combined by the
    router weights. Keeps static shapes (no capacity/dropping) while
    preserving router semantics — the router-sync bug (#6) lives in how the
    coordinator synchronizes ``wr`` gradients across the TP group.

    x: [B,S,D]; wr: [D,E]; w1: [E,D,Fp]; b1: [E,Fp]; w2: [E,Fp,D]
    """
    combine = router_ref(x, wr)  # [B,S,E]
    ys = []
    for e in range(w1.shape[0]):
        ys.append(mlp_ref(x, w1[e], b1[e], w2[e]).astype(F32))
    y = jnp.stack(ys, axis=-1)  # [B,S,D,E]
    out = jnp.einsum("bsde,bse->bsd", y, combine)
    return out.astype(BF16)
