"""AOT lowering: every (module, shape-variant) the Rust coordinator needs,
as HLO *text* artifacts plus a manifest.json.

HLO text — NOT ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run as ``python -m compile.aot --out ../artifacts`` (from python/). Python
runs ONLY here, at build time; the Rust binary is self-contained afterwards.

The model/parallelism configurations below must stay in lock-step with
``rust/src/model/config.rs`` (same names, same dims): the Rust side
recomputes each module's shape-parameter tuple and loads the artifact whose
key is ``model.module_key(name, params)``.
"""

import argparse
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model


# ---------------------------------------------------------------------------
# Model configurations. dims: B=microbatch, S=sequence, D=hidden, H=heads,
# F=ffn, V=vocab, E=experts. Variants: (tp, cp, sp) parallel layouts to
# pre-lower; fp8/moe: whether to emit those module families for the config.
# ---------------------------------------------------------------------------

CONFIGS = {
    # tiny: unit/integration tests and most benches
    "tiny": dict(B=2, S=16, D=32, H=4, F=64, V=64, E=2,
                 variants=[(1, 1, 0), (2, 1, 0), (2, 1, 1), (1, 2, 0),
                           (2, 2, 0), (2, 2, 1), (4, 1, 0)],
                 fp8=True, moe=True),
    # small: figure benches (deeper sweeps, wider layers)
    "small": dict(B=2, S=32, D=64, H=4, F=256, V=256, E=2,
                  variants=[(1, 1, 0), (2, 1, 0), (2, 1, 1), (1, 2, 0),
                            (2, 2, 0)],
                  fp8=True, moe=True),
    # e2e: the end-to-end training example (~10M params at L=8; scaled for
    # the single-CPU-core testbed — see EXPERIMENTS.md)
    "e2e": dict(B=4, S=128, D=256, H=8, F=1024, V=2048, E=2,
                variants=[(1, 1, 0), (2, 1, 0)],
                fp8=False, moe=False),
}


def variant_requests(cfg, tp, cp, sp, fp8, moe):
    """The set of (module-name, shape-params) a (tp, cp, sp) layout needs.

    Mirrors rust/src/model/config.rs::module_plan — keep in sync.
    """
    b, s, d, h, f, v, e = (cfg[k] for k in "BSDHFVE")
    hd = d // h
    t_cp = s // cp            # local sequence inside the attention block
    t_sp = t_cp // tp if sp else t_cp  # sequence at LN/residual points
    dp_, hp, fp_, vp = 3 * d // tp, h // tp, f // tp, v // tp
    reqs = [
        ("embed_fwd", (b, t_cp, vp, d)),
        ("embed_bwd", (b, t_cp, vp, d)),
        ("ln_fwd", (b, t_sp, d)),
        ("ln_bwd", (b, t_sp, d)),
        ("linear_fwd", (b, t_cp, d, dp_)),          # fused QKV (column-par)
        ("linear_bwd", (b, t_cp, d, dp_)),
        ("attn_fwd", (b, hp, t_cp, s, hd)),         # K/V allgathered over cp
        ("attn_bwd", (b, hp, t_cp, s, hd)),
        ("linearnb_fwd", (b, t_cp, hp * hd, d)),    # out proj (row-par)
        ("linearnb_bwd", (b, t_cp, hp * hd, d)),
        ("mlp_fwd", (b, t_cp, d, fp_)),
        ("mlp_bwd", (b, t_cp, d, fp_)),
        ("lmhead_fwd", (b, t_cp, d, vp)),
        ("logits_max", (b, t_cp, vp)),
        ("xent_local", (b, t_cp, vp)),
        ("lmhead_bwd", (b, t_cp, d, vp)),
    ]
    if fp8:
        reqs += [
            ("linear_fp8_fwd", (b, t_cp, d, dp_)),
            ("linear_fp8_bwd", (b, t_cp, d, dp_)),
            ("linearnb_fp8_fwd", (b, t_cp, hp * hd, d)),
            ("linearnb_fp8_bwd", (b, t_cp, hp * hd, d)),
            ("mlp_fp8_fwd", (b, t_cp, d, fp_)),
            ("mlp_fp8_bwd", (b, t_cp, d, fp_)),
        ]
    if moe:
        reqs += [
            # router runs on the SP-sharded sequence (bug #6's habitat)
            ("router_fwd", (b, t_sp, d, e)),
            ("router_bwd", (b, t_sp, d, e)),
            ("experts_fwd", (b, t_cp, d, fp_, e)),
            ("experts_bwd", (b, t_cp, d, fp_, e)),
        ]
    return reqs


def build_plan():
    """Global deduped {key: (name, params)} across all configs/variants."""
    plan = {}
    for cfg in CONFIGS.values():
        for (tp, cp, sp) in cfg["variants"]:
            for fp8 in ([False, True] if cfg["fp8"] and cp == 1 else [False]):
                moe = cfg["moe"] and cp == 1 and not fp8
                for name, params in variant_requests(cfg, tp, cp, sp,
                                                     fp8, moe):
                    plan[model.module_key(name, params)] = (name, params)
    return plan


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"bfloat16": "bf16", "float32": "f32", "int32": "i32",
            "float64": "f64", "int64": "i64"}[str(dt)]


def lower_one(name, params):
    fn, spec_builder = model.MODULES[name]
    specs = spec_builder(params)
    # keep_unused: module signatures are a fixed ABI with the Rust runtime —
    # never let jit prune arguments the math happens not to need (e.g.
    # embed_bwd's table).
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    ins = [[_dtype_name(s.dtype)] + list(s.shape) for s in specs]
    outs = [[_dtype_name(o.dtype)] + list(o.shape)
            for o in lowered.out_info]
    return text, ins, outs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated key prefixes to (re)build")
    args = ap.parse_args()
    outdir = os.path.abspath(args.out)
    hlodir = os.path.join(outdir, "hlo")
    os.makedirs(hlodir, exist_ok=True)

    plan = build_plan()
    keys = sorted(plan)
    if args.only:
        prefixes = args.only.split(",")
        keys = [k for k in keys if any(k.startswith(p) for p in prefixes)]

    manifest_path = os.path.join(outdir, "manifest.json")
    manifest = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f).get("modules", {})

    t0 = time.time()
    built = 0
    for i, key in enumerate(keys):
        name, params = plan[key]
        fname = f"hlo/{key}.hlo.txt"
        fpath = os.path.join(outdir, fname)
        if key in manifest and os.path.exists(fpath):
            continue  # incremental: Makefile handles source-change staleness
        text, ins, outs = lower_one(name, params)
        with open(fpath, "w") as f:
            f.write(text)
        manifest[key] = {"name": name, "params": list(params),
                         "file": fname, "inputs": ins, "outputs": outs}
        built += 1
        print(f"[{i + 1}/{len(keys)}] {key}  ({time.time() - t0:.1f}s)",
              file=sys.stderr)

    with open(manifest_path, "w") as f:
        json.dump({"version": 1, "modules": manifest}, f, indent=1,
                  sort_keys=True)
    print(f"built {built} new, total {len(manifest)} artifacts in "
          f"{time.time() - t0:.1f}s -> {outdir}")


if __name__ == "__main__":
    main()
