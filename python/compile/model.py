"""L2: the module set of the GPT/MoE model, as individually-lowerable
JAX functions (forward + backward).

TTrace's whole point is observing *per-module* intermediate tensors, so the
model is NOT lowered as one fused graph: every module's forward and
backward is its own HLO computation. The Rust coordinator (L3) chains them
— manual backprop — which gives exactly the hook surface the paper gets
from PyTorch module hooks, and places every collective *between* module
executions in Rust, which is where Megatron's silent bugs live.

Backward modules are lowered as ``jax.vjp`` of the reference forward,
recomputing the forward inside the backward (activation-recomputation
style), so no saved intermediates cross the Rust/HLO boundary.

Every function is shape-polymorphic in Python; ``aot.py`` instantiates the
concrete shape variants each parallelism configuration needs and emits one
HLO text artifact per (module, shape) with a deterministic key that the
Rust manifest loader recomputes.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.attention import attention_pallas, attention_bwd_formula

BF16 = jnp.bfloat16
F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Forward modules
# ---------------------------------------------------------------------------

def embed_fwd(tokens, table, offset):
    return (ref.embed_ref(tokens, table, offset),)


def embed_bwd(tokens, table, offset, dy):
    _, vjp = jax.vjp(lambda t: ref.embed_ref(tokens, t, offset), table)
    (dtable,) = vjp(dy)
    return (dtable,)


def ln_fwd(x, gamma, beta):
    return (ref.layernorm_ref(x, gamma, beta),)


def ln_bwd(x, gamma, beta, dy):
    _, vjp = jax.vjp(ref.layernorm_ref, x, gamma, beta)
    return vjp(dy)  # (dx, dgamma, dbeta)


def linear_fwd(x, w, b):
    return (ref.linear_ref(x, w, b),)


def linear_bwd(x, w, b, dy):
    _, vjp = jax.vjp(ref.linear_ref, x, w, b)
    return vjp(dy)  # (dx, dw, db)


def linearnb_fwd(x, w):
    return (ref.linear_ref(x, w),)


def linearnb_bwd(x, w, dy):
    _, vjp = jax.vjp(lambda x, w: ref.linear_ref(x, w), x, w)
    return vjp(dy)  # (dx, dw)


def attn_fwd(q, k, v, mask):
    return (attention_pallas(q, k, v, mask),)


def attn_bwd(q, k, v, mask, do):
    return attention_bwd_formula(q, k, v, mask, do)  # (dq, dk, dv)


def mlp_fwd(x, w1, b1, w2):
    return (ref.mlp_ref(x, w1, b1, w2),)


def mlp_bwd(x, w1, b1, w2, dy):
    _, vjp = jax.vjp(ref.mlp_ref, x, w1, b1, w2)
    return vjp(dy)  # (dx, dw1, db1, dw2)


def lmhead_fwd(x, table):
    return (ref.lmhead_logits_ref(x, table),)


def logits_max(logits):
    return (jnp.max(logits, axis=-1),)


def xent_local(logits, targets, offset, gmax):
    return ref.xent_local_ref(logits, targets, offset, gmax)


def lmhead_bwd(x, table, targets, offset, gmax, gsum, scale):
    """Recomputes local logits, forms dlogits, and backprops through the
    (tied) LM head. Returns (dx bf16, dtable bf16)."""
    logits = ref.lmhead_logits_ref(x, table)
    dlogits = ref.xent_dlogits_ref(logits, targets, offset, gmax, gsum,
                                   scale)
    dx = jnp.matmul(dlogits, table.astype(F32),
                    preferred_element_type=F32).astype(BF16)
    dlf = dlogits.reshape(-1, dlogits.shape[-1])
    xf = x.reshape(-1, x.shape[-1]).astype(F32)
    dtable = jnp.matmul(dlf.T, xf, preferred_element_type=F32).astype(BF16)
    return dx, dtable


# ---------------------------------------------------------------------------
# FP8-emulated linears (delayed scaling; scales are coordinator inputs).
# Gradients use the straight-through estimator through the quantizer, with
# e5m2-emulated gradient quantization — the TransformerEngine hybrid recipe.
# ---------------------------------------------------------------------------

E5M2_MAX = 57344.0


def _qdq_e5m2(x, scale):
    xf = x.astype(F32) * scale
    xf = jnp.clip(xf, -E5M2_MAX, E5M2_MAX)
    return xf.astype(jnp.float8_e5m2).astype(F32) / scale


def linear_fp8_fwd(x, w, b, sx, sw):
    return (ref.linear_fp8_ref(x, w, sx, sw, b),)


def linear_fp8_bwd(x, w, sx, sw, sdy, dy):
    """dx = dyq @ wq^T ; dw = xq^T @ dyq ; db = sum(dy)."""
    xq = ref.fp8_quant_dequant_ref(x, sx)
    wq = ref.fp8_quant_dequant_ref(w, sw)
    dyq = _qdq_e5m2(dy, sdy)
    dx = jnp.matmul(dyq, wq.T, preferred_element_type=F32).astype(BF16)
    dyf = dyq.reshape(-1, dyq.shape[-1])
    xf = xq.reshape(-1, xq.shape[-1])
    dw = jnp.matmul(xf.T, dyf, preferred_element_type=F32).astype(BF16)
    db = jnp.sum(dy.astype(F32), axis=tuple(range(dy.ndim - 1))).astype(BF16)
    return dx, dw, db


def linearnb_fp8_fwd(x, w, sx, sw):
    return (ref.linear_fp8_ref(x, w, sx, sw),)


def linearnb_fp8_bwd(x, w, sx, sw, sdy, dy):
    dx, dw, _ = linear_fp8_bwd(x, w, sx, sw, sdy, dy)
    return dx, dw


def mlp_fp8_fwd(x, w1, b1, w2, sx, sw1, sh, sw2):
    """FP8-emulated fused MLP: fc1(e4m3) -> gelu(f32) -> fc2(e4m3).

    Also returns amax of the (internal) post-gelu activation so the
    coordinator can run delayed scaling for `sh` — the activation never
    leaves the device, mirroring TransformerEngine's amax history.
    """
    h = ref.linear_fp8_ref(x, w1, sx, sw1, b1)
    a = ref.gelu(h).astype(BF16)
    y = ref.linear_fp8_ref(a, w2, sh, sw2)
    amax_a = jnp.max(jnp.abs(a.astype(F32)))
    return y, amax_a


def mlp_fp8_bwd(x, w1, b1, w2, sx, sw1, sh, sw2, sdy, dy):
    """Straight-through-quantizer backward of mlp_fp8_fwd (recomputes the
    forward; e5m2 gradient quantization on both GEMMs)."""
    h = ref.linear_fp8_ref(x, w1, sx, sw1, b1)  # bf16 [.., Fp]
    a = ref.gelu(h).astype(BF16)
    aq = ref.fp8_quant_dequant_ref(a, sh)
    w2q = ref.fp8_quant_dequant_ref(w2, sw2)
    dyq = _qdq_e5m2(dy, sdy)
    da = jnp.matmul(dyq, w2q.T, preferred_element_type=F32)
    dw2 = jnp.matmul(aq.reshape(-1, aq.shape[-1]).T,
                     dyq.reshape(-1, dyq.shape[-1]),
                     preferred_element_type=F32).astype(BF16)
    # gelu'(h) in f32
    _, gelu_vjp = jax.vjp(lambda t: ref.gelu(t), h)
    (dh,) = gelu_vjp(da)
    dh = dh.astype(BF16)
    dhq = _qdq_e5m2(dh, sdy)
    xq = ref.fp8_quant_dequant_ref(x, sx)
    w1q = ref.fp8_quant_dequant_ref(w1, sw1)
    dx = jnp.matmul(dhq, w1q.T, preferred_element_type=F32).astype(BF16)
    dw1 = jnp.matmul(xq.reshape(-1, xq.shape[-1]).T,
                     dhq.reshape(-1, dhq.shape[-1]),
                     preferred_element_type=F32).astype(BF16)
    db1 = jnp.sum(dh.astype(F32), axis=tuple(range(dh.ndim - 1))).astype(BF16)
    return dx, dw1, db1, dw2


# ---------------------------------------------------------------------------
# Dense top-1 MoE layer, split into router and experts so the coordinator
# can compute the router on the *sequence-parallel-sharded* input (that is
# where Megatron's router-sync bug #6 lives: under SP each TP rank sees a
# different sequence shard, so router weight grads MUST be all-reduced over
# the TP group).
# ---------------------------------------------------------------------------

def router_fwd(x, wr):
    return (ref.router_ref(x, wr),)


def router_bwd(x, wr, dcombine):
    _, vjp = jax.vjp(ref.router_ref, x, wr)
    return vjp(dcombine)  # (dx, dwr)


def _experts(x, w1, b1, w2, combine):
    ys = []
    for e in range(w1.shape[0]):
        ys.append(ref.mlp_ref(x, w1[e], b1[e], w2[e]).astype(F32))
    y = jnp.stack(ys, axis=-1)  # [B,S,D,E]
    out = jnp.einsum("bsde,bse->bsd", y, combine)
    return out.astype(BF16)


def experts_fwd(x, w1, b1, w2, combine):
    return (_experts(x, w1, b1, w2, combine),)


def experts_bwd(x, w1, b1, w2, combine, dy):
    _, vjp = jax.vjp(_experts, x, w1, b1, w2, combine)
    return vjp(dy)  # (dx, dw1, db1, dw2, dcombine)


# ---------------------------------------------------------------------------
# Module registry: name -> (fn, input-spec builder)
#
# Each spec builder takes the module's shape-parameter tuple (the same tuple
# the Rust side uses to form the artifact key) and returns the list of
# ShapeDtypeStructs to lower with.
# ---------------------------------------------------------------------------

def _embed_specs(p):
    b, t, vp, d = p
    return [spec((b, t), I32), spec((vp, d), BF16), spec((), I32)]


def _embed_bwd_specs(p):
    b, t, vp, d = p
    return _embed_specs(p) + [spec((b, t, d), BF16)]


def _ln_specs(p):
    b, t, d = p
    return [spec((b, t, d), BF16), spec((d,), BF16), spec((d,), BF16)]


def _ln_bwd_specs(p):
    b, t, d = p
    return _ln_specs(p) + [spec((b, t, d), BF16)]


def _linear_specs(p):
    b, t, din, dout = p
    return [spec((b, t, din), BF16), spec((din, dout), BF16),
            spec((dout,), BF16)]


def _linear_bwd_specs(p):
    b, t, din, dout = p
    return _linear_specs(p) + [spec((b, t, dout), BF16)]


def _linearnb_specs(p):
    b, t, din, dout = p
    return [spec((b, t, din), BF16), spec((din, dout), BF16)]


def _linearnb_bwd_specs(p):
    b, t, din, dout = p
    return _linearnb_specs(p) + [spec((b, t, dout), BF16)]


def _attn_specs(p):
    b, hp, sq, skv, hd = p
    return [spec((b, hp, sq, hd), BF16), spec((b, hp, skv, hd), BF16),
            spec((b, hp, skv, hd), BF16), spec((sq, skv), F32)]


def _attn_bwd_specs(p):
    b, hp, sq, skv, hd = p
    return _attn_specs(p) + [spec((b, hp, sq, hd), BF16)]


def _mlp_specs(p):
    b, t, d, fp = p
    return [spec((b, t, d), BF16), spec((d, fp), BF16), spec((fp,), BF16),
            spec((fp, d), BF16)]


def _mlp_bwd_specs(p):
    b, t, d, fp = p
    return _mlp_specs(p) + [spec((b, t, d), BF16)]


def _lmhead_specs(p):
    b, t, d, vp = p
    return [spec((b, t, d), BF16), spec((vp, d), BF16)]


def _logits_max_specs(p):
    b, t, vp = p
    return [spec((b, t, vp), F32)]


def _xent_local_specs(p):
    b, t, vp = p
    return [spec((b, t, vp), F32), spec((b, t), I32), spec((), I32),
            spec((b, t), F32)]


def _lmhead_bwd_specs(p):
    b, t, d, vp = p
    return [spec((b, t, d), BF16), spec((vp, d), BF16), spec((b, t), I32),
            spec((), I32), spec((b, t), F32), spec((b, t), F32),
            spec((b, t), F32)]


def _linear_fp8_specs(p):
    b, t, din, dout = p
    return [spec((b, t, din), BF16), spec((din, dout), BF16),
            spec((dout,), BF16), spec((), F32), spec((), F32)]


def _linear_fp8_bwd_specs(p):
    b, t, din, dout = p
    return [spec((b, t, din), BF16), spec((din, dout), BF16), spec((), F32),
            spec((), F32), spec((), F32), spec((b, t, dout), BF16)]


def _linearnb_fp8_specs(p):
    b, t, din, dout = p
    return [spec((b, t, din), BF16), spec((din, dout), BF16), spec((), F32),
            spec((), F32)]


def _linearnb_fp8_bwd_specs(p):
    b, t, din, dout = p
    return _linearnb_fp8_specs(p) + [spec((), F32),
                                     spec((b, t, dout), BF16)]


def _mlp_fp8_specs(p):
    b, t, d, fp = p
    return [spec((b, t, d), BF16), spec((d, fp), BF16), spec((fp,), BF16),
            spec((fp, d), BF16), spec((), F32), spec((), F32), spec((), F32),
            spec((), F32)]


def _mlp_fp8_bwd_specs(p):
    b, t, d, fp = p
    return _mlp_fp8_specs(p) + [spec((), F32), spec((b, t, d), BF16)]


def _router_specs(p):
    b, t, d, e = p
    return [spec((b, t, d), BF16), spec((d, e), BF16)]


def _router_bwd_specs(p):
    b, t, d, e = p
    return _router_specs(p) + [spec((b, t, e), F32)]


def _experts_specs(p):
    b, t, d, fp, e = p
    return [spec((b, t, d), BF16), spec((e, d, fp), BF16),
            spec((e, fp), BF16), spec((e, fp, d), BF16),
            spec((b, t, e), F32)]


def _experts_bwd_specs(p):
    b, t, d, fp, e = p
    return _experts_specs(p) + [spec((b, t, d), BF16)]


MODULES = {
    "embed_fwd": (embed_fwd, _embed_specs),
    "embed_bwd": (embed_bwd, _embed_bwd_specs),
    "ln_fwd": (ln_fwd, _ln_specs),
    "ln_bwd": (ln_bwd, _ln_bwd_specs),
    "linear_fwd": (linear_fwd, _linear_specs),
    "linear_bwd": (linear_bwd, _linear_bwd_specs),
    "linearnb_fwd": (linearnb_fwd, _linearnb_specs),
    "linearnb_bwd": (linearnb_bwd, _linearnb_bwd_specs),
    "attn_fwd": (attn_fwd, _attn_specs),
    "attn_bwd": (attn_bwd, _attn_bwd_specs),
    "mlp_fwd": (mlp_fwd, _mlp_specs),
    "mlp_bwd": (mlp_bwd, _mlp_bwd_specs),
    "lmhead_fwd": (lmhead_fwd, _lmhead_specs),
    "logits_max": (logits_max, _logits_max_specs),
    "xent_local": (xent_local, _xent_local_specs),
    "lmhead_bwd": (lmhead_bwd, _lmhead_bwd_specs),
    "linear_fp8_fwd": (linear_fp8_fwd, _linear_fp8_specs),
    "linear_fp8_bwd": (linear_fp8_bwd, _linear_fp8_bwd_specs),
    "linearnb_fp8_fwd": (linearnb_fp8_fwd, _linearnb_fp8_specs),
    "linearnb_fp8_bwd": (linearnb_fp8_bwd, _linearnb_fp8_bwd_specs),
    "mlp_fp8_fwd": (mlp_fp8_fwd, _mlp_fp8_specs),
    "mlp_fp8_bwd": (mlp_fp8_bwd, _mlp_fp8_bwd_specs),
    "router_fwd": (router_fwd, _router_specs),
    "router_bwd": (router_bwd, _router_bwd_specs),
    "experts_fwd": (experts_fwd, _experts_specs),
    "experts_bwd": (experts_bwd, _experts_bwd_specs),
}


def module_key(name, params) -> str:
    """Deterministic artifact key; the Rust manifest loader recomputes this
    exact string. Example: ``attn_fwd__2_4_16_16_8``."""
    return name + "__" + "_".join(str(int(x)) for x in params)
