"""L1 correctness: the Pallas attention kernel vs the pure-jnp oracle,
swept over shapes/masks with hypothesis. This is the CORE correctness
signal for the kernel that ends up inside every attn_fwd HLO artifact."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention_pallas, attention_bwd_formula

BF16_EPS = 0.0078125


def rand(rng, shape, dtype=jnp.bfloat16, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def rel_err(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    denom = np.linalg.norm(a)
    return np.linalg.norm(a - b) / max(denom, 1e-30)


def causal_mask(sq, skv, offset=0):
    m = np.zeros((sq, skv), np.float32)
    for i in range(sq):
        m[i, i + 1 + offset:] = ref.MASK_VALUE
    return jnp.asarray(m)


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([1, 2, 4]),
    sq=st.sampled_from([4, 8, 16, 32]),
    hd=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_attention_pallas_matches_ref(b, h, sq, hd, seed):
    rng = np.random.default_rng(seed)
    skv = sq  # self-attention shapes as used by the model
    q = rand(rng, (b, h, sq, hd))
    k = rand(rng, (b, h, skv, hd))
    v = rand(rng, (b, h, skv, hd))
    mask = causal_mask(sq, skv)
    out_ref = ref.attention_ref(q, k, v, mask)
    out_pal = attention_pallas(q, k, v, mask)
    assert rel_err(out_ref, out_pal) < 4 * BF16_EPS


@settings(max_examples=10, deadline=None)
@given(
    sq=st.sampled_from([4, 8]),
    skv=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_pallas_cross_attention_shapes(sq, skv, seed):
    """CP-style shapes: local queries over a longer (gathered) K/V."""
    rng = np.random.default_rng(seed)
    q = rand(rng, (2, 2, sq, 8))
    k = rand(rng, (2, 2, skv, 8))
    v = rand(rng, (2, 2, skv, 8))
    mask = causal_mask(sq, skv, offset=skv - sq)
    assert rel_err(ref.attention_ref(q, k, v, mask),
                   attention_pallas(q, k, v, mask)) < 4 * BF16_EPS


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_attention_bwd_matches_vjp(seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, (2, 2, 8, 8))
    k = rand(rng, (2, 2, 8, 8))
    v = rand(rng, (2, 2, 8, 8))
    mask = causal_mask(8, 8)
    do = rand(rng, (2, 2, 8, 8))
    dq, dk, dv = attention_bwd_formula(q, k, v, mask, do)
    _, vjp = jax.vjp(lambda q, k, v: ref.attention_ref(q, k, v, mask), q, k, v)
    dq2, dk2, dv2 = vjp(do)
    for a, b in [(dq, dq2), (dk, dk2), (dv, dv2)]:
        assert rel_err(b, a) < 8 * BF16_EPS


def test_attention_fully_masked_rows_are_finite():
    """The kernel must stay total even for rows with no visible key."""
    q = jnp.ones((1, 1, 4, 8), jnp.bfloat16)
    k = jnp.ones((1, 1, 4, 8), jnp.bfloat16)
    v = jnp.ones((1, 1, 4, 8), jnp.bfloat16)
    mask = jnp.full((4, 4), ref.MASK_VALUE, jnp.float32)
    out = attention_pallas(q, k, v, mask)
    assert np.isfinite(np.asarray(out, np.float32)).all()


@settings(max_examples=15, deadline=None)
@given(
    scale=st.floats(0.5, 400.0),
    seed=st.integers(0, 2**16),
)
def test_fp8_quant_dequant_error_bound(scale, seed):
    """e4m3 quantize-dequantize keeps relative error under eps(e4m3)/2 for
    values inside the representable band."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0.01, 440.0 / scale, (256,)), jnp.bfloat16)
    y = ref.fp8_quant_dequant_ref(x, scale)
    err = np.abs(np.asarray(y) - np.asarray(x, np.float32)) / np.asarray(x, np.float32)
    assert err.max() < 0.0665, err.max()  # eps(e4m3)/2 + bf16 slack


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_router_combine_is_one_hot_prob(seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, (2, 8, 16))
    wr = rand(rng, (16, 4), scale=0.1)
    c = np.asarray(ref.router_ref(x, wr))
    nz = (c > 0).sum(axis=-1)
    assert (nz <= 1).all()  # top-1: at most one expert per token
    assert (c.max(axis=-1) <= 1.0 + 1e-6).all()
    assert (c >= 0).all()


def test_layernorm_ref_stats():
    rng = np.random.default_rng(0)
    x = rand(rng, (2, 4, 64), scale=5.0)
    g = jnp.ones((64,), jnp.bfloat16)
    b = jnp.zeros((64,), jnp.bfloat16)
    y = np.asarray(ref.layernorm_ref(x, g, b), np.float32)
    assert abs(y.mean()) < 0.02
    assert abs(y.std() - 1.0) < 0.05
