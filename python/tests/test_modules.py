"""L2 correctness: module fwd/bwd semantics (shapes, gradient consistency,
distributed-identity properties the Rust coordinator relies on) and the
AOT plan's integrity (every request lowers; keys are stable)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref

BF16_EPS = 0.0078125


def rand(rng, shape, dtype=jnp.bfloat16, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def rel_err(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return np.linalg.norm(a - b) / max(np.linalg.norm(a), 1e-30)


# ---------------------------------------------------------------------------
# distributed-identity properties (what TP/vocab sharding relies on)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_vocab_sharded_embedding_sums_to_full(seed):
    """sum over shards of masked lookups == full-table lookup (bug #1 is
    exactly a violation of this identity)."""
    rng = np.random.default_rng(seed)
    v, d, tp = 32, 8, 4
    table = rand(rng, (v, d), scale=0.02)
    tokens = jnp.asarray(rng.integers(0, v, (2, 6)), jnp.int32)
    full = np.asarray(ref.embed_ref(tokens, table, jnp.int32(0)), np.float32)
    parts = np.zeros_like(full)
    for r in range(tp):
        shard = table[r * v // tp:(r + 1) * v // tp]
        parts += np.asarray(
            ref.embed_ref(tokens, shard, jnp.int32(r * v // tp)), np.float32)
    np.testing.assert_allclose(parts, full, rtol=0, atol=0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_row_parallel_linear_partials_sum_to_full(seed):
    rng = np.random.default_rng(seed)
    din, dout, tp = 16, 8, 2
    x = rand(rng, (2, 4, din))
    w = rand(rng, (din, dout), scale=0.1)
    full = np.asarray(ref.linear_ref(x, w), np.float32)
    acc = np.zeros_like(full, dtype=np.float64)
    for r in range(tp):
        xs = x[..., r * din // tp:(r + 1) * din // tp]
        ws = w[r * din // tp:(r + 1) * din // tp]
        acc += np.asarray(ref.linear_ref(xs, ws), np.float64)
    # partials summed in f64 match the full matmul within bf16 round-off
    assert rel_err(full, acc) < 4 * BF16_EPS


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_vocab_parallel_xent_matches_dense_softmax(seed):
    """two-phase global-max/sumexp cross-entropy == direct log_softmax."""
    rng = np.random.default_rng(seed)
    b, s, v, tp = 2, 4, 16, 2
    logits = jnp.asarray(rng.standard_normal((b, s, v)) * 3, jnp.float32)
    targets = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    # dense reference
    dense = -jax.nn.log_softmax(logits)[
        jnp.arange(b)[:, None], jnp.arange(s)[None, :], targets]
    # sharded two-phase
    gmax = jnp.max(logits, axis=-1)
    gsum = jnp.zeros((b, s), jnp.float32)
    tsum = jnp.zeros((b, s), jnp.float32)
    for r in range(tp):
        shard = logits[..., r * v // tp:(r + 1) * v // tp]
        se, tl = ref.xent_local_ref(shard, targets, jnp.int32(r * v // tp), gmax)
        gsum += se
        tsum += tl
    loss = jnp.log(gsum) - tsum
    np.testing.assert_allclose(np.asarray(loss), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_xent_dlogits_rowsum_zero_offdiag(seed):
    """dlogits rows sum to (p - onehot) * scale -> sums to 0 per token when
    the shard covers the whole vocab."""
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((2, 3, 8)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, 8, (2, 3)), jnp.int32)
    gmax = jnp.max(logits, axis=-1)
    gsum, _ = ref.xent_local_ref(logits, targets, jnp.int32(0), gmax)
    scale = jnp.ones((2, 3), jnp.float32)
    d = ref.xent_dlogits_ref(logits, targets, jnp.int32(0), gmax, gsum, scale)
    np.testing.assert_allclose(np.asarray(d).sum(-1), 0.0, atol=1e-5)


def test_mlp_bwd_matches_numerical_gradient():
    rng = np.random.default_rng(1)
    x = rand(rng, (1, 2, 8), jnp.float32, 0.5).astype(jnp.bfloat16)
    w1 = rand(rng, (8, 16), scale=0.2)
    b1 = jnp.zeros((16,), jnp.bfloat16)
    w2 = rand(rng, (16, 8), scale=0.2)
    dy = rand(rng, (1, 2, 8))
    dx, dw1, db1, dw2 = model.mlp_bwd(x, w1, b1, w2, dy)
    # directional derivative check in f32
    eps = 1e-2
    u = rand(rng, (8, 16), jnp.float32, 1.0)
    f = lambda w: jnp.sum(ref.mlp_ref(x, w.astype(jnp.bfloat16), b1, w2)
                          .astype(jnp.float32) * dy.astype(jnp.float32))
    w1f = w1.astype(jnp.float32)
    num = (f(w1f + eps * u) - f(w1f - eps * u)) / (2 * eps)
    ana = jnp.sum(dw1.astype(jnp.float32) * u)
    assert abs(float(num - ana)) / max(abs(float(num)), 1e-6) < 0.08


# ---------------------------------------------------------------------------
# AOT plan integrity
# ---------------------------------------------------------------------------

def test_plan_covers_all_configs_and_keys_are_stable():
    plan = aot.build_plan()
    assert len(plan) > 150
    # the Rust side hard-codes this format
    assert "attn_fwd__2_4_16_16_8" in plan
    for key, (name, params) in plan.items():
        assert model.module_key(name, params) == key
        assert name in model.MODULES


@pytest.mark.parametrize("name,params", [
    ("ln_fwd", (2, 16, 32)),
    ("linear_bwd", (2, 16, 32, 96)),
    ("lmhead_bwd", (2, 16, 32, 32)),
    ("experts_bwd", (2, 16, 32, 32, 2)),
    ("mlp_fp8_fwd", (2, 16, 32, 32)),
])
def test_modules_lower_with_stable_abi(name, params):
    text, ins, outs = aot.lower_one(name, params)
    assert text.startswith("HloModule")
    fn, spec_builder = model.MODULES[name]
    assert len(ins) == len(spec_builder(params))
    assert len(outs) >= 1


def test_lowered_io_matches_manifest_on_disk():
    import json
    import os
    mpath = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    manifest = json.load(open(mpath))["modules"]
    plan = aot.build_plan()
    missing = [k for k in plan if k not in manifest]
    assert not missing, f"stale artifacts — run make artifacts: {missing[:5]}"
